//! Diagnostic and certificate types for the static plan verifier.
//!
//! A [`Diagnostic`] names the check class that fired, the rewrite rule
//! whose trail event most recently touched the offending node (so a bad
//! rewrite is attributed to the pass that made it), and a rendered
//! node path — enough to locate the violation in `plan.describe()`
//! output without re-running anything. A [`Certificate`] is the
//! positive counterpart: a summary of everything that was proved, kept
//! cheap enough to log at plan birth.

use std::fmt;

use crate::fusion::{RewriteEvent, Rule};
use crate::ir::{Graph, NodeId, Op};

/// Which of the verifier's four checks produced a diagnostic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckClass {
    /// Check 1: independent shape/broadcast re-inference disagrees with
    /// a stored node shape, or a rewritten pipeline is structurally
    /// malformed (roles missing, elimination bound exceeded).
    ShapeInference,
    /// Check 2: the write-set/alias analysis over the `LogicalGrid`
    /// decomposition could not prove disjoint writes + immutable reads.
    RaceFreedom,
    /// Check 3: a rewrite reorders a non-associative f32 reduction
    /// outside the blessed online-softmax contract.
    Determinism,
    /// Check 4: a `BlockMask` tile class is not justified by the mask
    /// predicate (unsound skip or mask elision).
    MaskSkip,
}

impl fmt::Display for CheckClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            CheckClass::ShapeInference => "shape-inference",
            CheckClass::RaceFreedom => "race-freedom",
            CheckClass::Determinism => "float-determinism",
            CheckClass::MaskSkip => "mask-skip",
        })
    }
}

/// One verification failure, attributed to a node and (when the rewrite
/// trail covers that node) to the rule that last touched it.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub check: CheckClass,
    /// The last `RewriteEvent` logged at `node`, if any — the rewrite
    /// most likely responsible for the violation.
    pub rule: Option<Rule>,
    pub node: Option<NodeId>,
    /// Rendered node path, e.g. `n7 = Add(n3, n5) [2, 4, 64, 64]`.
    pub path: String,
    pub message: String,
}

impl Diagnostic {
    pub fn new(check: CheckClass, message: impl Into<String>) -> Self {
        Diagnostic {
            check,
            rule: None,
            node: None,
            path: String::new(),
            message: message.into(),
        }
    }

    /// Attach a node location: renders the node path and attributes the
    /// diagnostic to the last rewrite event logged at that node.
    pub fn with_node(mut self, g: &Graph, log: &[RewriteEvent], id: NodeId) -> Self {
        self.node = Some(id);
        self.rule = rule_at(log, id);
        self.path = node_path(g, id);
        self
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}]", self.check)?;
        if let Some(n) = self.node {
            write!(f, " n{}", n.0)?;
        }
        if let Some(r) = self.rule {
            write!(f, " (rule {r:?})")?;
        }
        write!(f, ": {}", self.message)?;
        if !self.path.is_empty() {
            write!(f, "\n    at {}", self.path)?;
        }
        Ok(())
    }
}

/// Everything a clean verification run proved, as counts: a cheap
/// machine-checked summary to log at plan birth.
#[derive(Debug, Clone, Default)]
pub struct Certificate {
    /// Name of the verified graph.
    pub graph: String,
    /// Nodes whose shapes were independently re-inferred (check 1).
    pub nodes_checked: usize,
    /// Kernel groups whose read sets were proved immutable (check 2).
    pub groups_checked: usize,
    /// Pipelines whose grid decomposition was re-derived (check 2).
    pub pipelines_checked: usize,
    /// Grid work items proved to write pairwise-disjoint output regions
    /// that exactly cover the output (check 2).
    pub blocks_proved_disjoint: usize,
    /// Rewrite-trail events walked and accounted for (check 3).
    pub rewrite_events_checked: usize,
    /// Mask-predicate cells brute-force re-evaluated (check 4).
    pub mask_cells_checked: usize,
    /// Empty tiles whose skip was proved sound (check 4).
    pub empty_tiles_proved: u64,
    /// The exp kernel was observed to pin the -1e30 sentinel to exactly
    /// 0.0 and exp(0) to exactly 1.0 (check 4's numeric premise).
    pub exp_cutoff_proved: bool,
}

impl fmt::Display for Certificate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} nodes, {} groups ({} pipelines, {} disjoint blocks), \
             {} rewrite events, {} mask cells ({} empty tiles proved)",
            self.nodes_checked,
            self.groups_checked,
            self.pipelines_checked,
            self.blocks_proved_disjoint,
            self.rewrite_events_checked,
            self.mask_cells_checked,
            self.empty_tiles_proved,
        )
    }
}

/// Render a one-line node path: id, op, operand ids, stored shape.
pub fn node_path(g: &Graph, id: NodeId) -> String {
    let node = g.node(id);
    let name = match &node.op {
        Op::Input { name } => format!("Input(\"{name}\")"),
        Op::Const { value } => format!("Const({value})"),
        Op::Iota { axis } => format!("Iota(axis={axis})"),
        Op::Pointwise { op, .. } => format!("{op:?}"),
        Op::Matmul { transpose_rhs, .. } => {
            if *transpose_rhs {
                "MatmulNT".to_string()
            } else {
                "Matmul".to_string()
            }
        }
        Op::Reduce { op, axis, .. } => format!("Reduce{op:?}(axis={axis})"),
        Op::Broadcast { .. } => "Broadcast".to_string(),
        Op::Slice { axis, start, len, .. } => {
            format!("Slice(axis={axis}, {start}..{})", start + len)
        }
    };
    let args: Vec<String> = node
        .op
        .input_ids()
        .iter()
        .map(|n| format!("n{}", n.0))
        .collect();
    format!("n{} = {}({}) {:?}", id.0, name, args.join(", "), node.shape)
}

/// The last rewrite event logged at `id`, if any: attribution for "which
/// pass introduced this".
pub fn rule_at(log: &[RewriteEvent], id: NodeId) -> Option<Rule> {
    log.iter().rev().find(|e| e.at == id).map(|e| e.rule)
}
