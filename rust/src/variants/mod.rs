//! Attention variants written against the idiomatic tensor API — the
//! analog of the paper's PyTorch listings. No variant uses a template or
//! a special attention node: each is plain IR (matmuls, iota-built masks,
//! two-pass softmax) that the compiler must discover and fuse (Listing 3
//! vs Listing 2 is the paper's whole point).
//!
//! GQA note: query heads are laid out as `[B, Hkv, G, S, D]` with kv
//! tensors `[B, Hkv, 1, S, D]`, so the group dimension broadcasts — the
//! structural equivalent of FlexAttention's `h // group` index mapping.

use crate::ir::{CmpOp, Graph, GraphBuilder, NodeId};

/// The seven FlexAttention-expressible variants plus the two beyond it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Variant {
    Vanilla,
    Causal,
    SlidingWindow { window: usize },
    Alibi,
    Softcap { cap: f32 },
    PrefixLm { prefix: usize },
    DocumentMask,
    DiffAttn { lambda: f32 },
    Evoformer,
    /// RSA-inspired rectified attention: positions whose *score* falls
    /// below a threshold are masked out. The mask depends on the data,
    /// not on (q, kv) indices — FlexAttention's `mask_mod` "only depends
    /// on the shape of Q and K" (§2.2), so this is outside its template;
    /// Flashlight fuses it like any other score chain (§3.8).
    Rectified { tau: f32 },
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Vanilla => "vanilla",
            Variant::Causal => "causal",
            Variant::SlidingWindow { .. } => "sliding_window",
            Variant::Alibi => "alibi",
            Variant::Softcap { .. } => "softcap",
            Variant::PrefixLm { .. } => "prefix_lm",
            Variant::DocumentMask => "document",
            Variant::DiffAttn { .. } => "diff_attn",
            Variant::Evoformer => "evoformer",
            Variant::Rectified { .. } => "rectified",
        }
    }

    /// Expressible in the FlexAttention template (Eq. 4)?
    pub fn flex_supported(&self) -> bool {
        !matches!(
            self,
            Variant::DiffAttn { .. } | Variant::Evoformer | Variant::Rectified { .. }
        )
    }

    /// Is this variant's serving arm causal in absolute positions — i.e.
    /// a cached row's attention (and therefore any deeper layer's K/V
    /// derived from it) never changes as the sequence grows? This is the
    /// precondition for conversation prefix reuse: vanilla serving
    /// attends the whole (growing) cache, so its prefixes are not
    /// reusable and the engine backend skips parking them.
    pub fn causal_serving(&self) -> bool {
        matches!(
            self,
            Variant::Causal
                | Variant::Softcap { .. }
                | Variant::SlidingWindow { .. }
                | Variant::Alibi
        )
    }

    /// Uses FlexAttention's `mask_mod`/`block_mask` path (vs `score_mod`)?
    pub fn is_mask_variant(&self) -> bool {
        matches!(
            self,
            Variant::Causal
                | Variant::SlidingWindow { .. }
                | Variant::PrefixLm { .. }
                | Variant::DocumentMask
        )
    }

    /// Fraction of (q, kv) pairs that are *kept* (visible), in exact
    /// arithmetic — drives the block-sparsity modeling of the baselines.
    pub fn density(&self, s: usize) -> f64 {
        match self {
            Variant::Vanilla | Variant::Alibi | Variant::Softcap { .. } => match self {
                Variant::Vanilla => 1.0,
                _ => 0.5 + 0.5 / s as f64, // causal footprint
            },
            Variant::Causal => 0.5 + 0.5 / s as f64,
            Variant::SlidingWindow { window } => {
                // sum over q of min(q+1, window+1) / s^2
                let w = *window as f64;
                let s_f = s as f64;
                let full_rows = (s_f - w - 1.0).max(0.0);
                let tri_rows = s_f - full_rows;
                (tri_rows * (tri_rows + 1.0) / 2.0 + full_rows * (w + 1.0)) / (s_f * s_f)
            }
            Variant::PrefixLm { prefix } => {
                let p = *prefix as f64;
                let s_f = s as f64;
                let causal = 0.5 + 0.5 / s_f;
                (causal * s_f * s_f + (s_f - p).max(0.0) * p / 2.0).min(s_f * s_f)
                    / (s_f * s_f)
            }
            Variant::DocumentMask => {
                // paper uses 12 documents: ~1/12 density block-diagonal
                1.0 / 12.0
            }
            Variant::DiffAttn { .. } => 1.0,
            Variant::Evoformer => 1.0,
            // Data-dependent: unknowable without the data; systems that
            // cannot inspect it must run dense.
            Variant::Rectified { .. } => 1.0,
        }
    }
}

/// Shape configuration matching the paper's §4.1 benchmark setup.
#[derive(Debug, Clone, Copy)]
pub struct AttnShape {
    pub batch: usize,
    /// Extra MSA-row dimension (Evoformer only; 1 otherwise). The pair
    /// bias is broadcast along it — the structure FlexAttention cannot
    /// express (§4.3).
    pub rows: usize,
    pub heads_q: usize,
    pub heads_kv: usize,
    pub seq: usize,
    pub head_dim: usize,
}

impl AttnShape {
    pub fn mha(batch: usize, seq: usize) -> Self {
        AttnShape {
            batch,
            rows: 1,
            heads_q: 16,
            heads_kv: 16,
            seq,
            head_dim: 64,
        }
    }

    pub fn gqa(batch: usize, seq: usize) -> Self {
        AttnShape {
            batch,
            rows: 1,
            heads_q: 16,
            heads_kv: 2,
            seq,
            head_dim: 64,
        }
    }

    /// Evoformer row-gated attention shape (paper §4.1: B 1..32, S=256,
    /// H=4, d in {64, 128}; MSA rows from the AlphaFold workload).
    pub fn evoformer(batch: usize, rows: usize, seq: usize, head_dim: usize) -> Self {
        AttnShape {
            batch,
            rows,
            heads_q: 4,
            heads_kv: 4,
            seq,
            head_dim,
        }
    }

    pub fn group(&self) -> usize {
        self.heads_q / self.heads_kv
    }

    /// 5-D layout [B, Hkv, G, S, D] used by the graphs.
    pub fn q_shape(&self) -> Vec<usize> {
        vec![
            self.batch,
            self.heads_kv,
            self.group(),
            self.seq,
            self.head_dim,
        ]
    }

    pub fn kv_shape(&self) -> Vec<usize> {
        vec![self.batch, self.heads_kv, 1, self.seq, self.head_dim]
    }
}

/// Shared body: scores -> (variant-specific mods) -> softmax -> PV.
fn attention_body(
    b: &mut GraphBuilder,
    q: NodeId,
    k: NodeId,
    v: NodeId,
    shape: &AttnShape,
    variant: Variant,
) -> NodeId {
    let scale = 1.0 / (shape.head_dim as f32).sqrt();
    let s0 = b.matmul_nt(q, k);
    let mut s = b.mul_scalar(s0, scale);
    let score_shape = b.shape(s).clone();
    let rank = score_shape.len();
    let (q_ax, k_ax) = (rank - 2, rank - 1);

    // Build the keep-mask / bias exactly the way idiomatic code does:
    // materialized iota index tensors compared elementwise (Listing 3).
    let keep = match variant {
        Variant::Vanilla | Variant::DiffAttn { .. } => None,
        Variant::Rectified { tau } => {
            // keep iff score >= tau: a *data-dependent* mask.
            let t = b.constant(tau, &score_shape);
            Some(b.cmp(CmpOp::Ge, s, t))
        }
        Variant::Causal => {
            let qi = b.iota(&score_shape, q_ax);
            let ki = b.iota(&score_shape, k_ax);
            Some(b.cmp(CmpOp::Le, ki, qi))
        }
        Variant::SlidingWindow { window } => {
            let qi = b.iota(&score_shape, q_ax);
            let ki = b.iota(&score_shape, k_ax);
            let causal = b.cmp(CmpOp::Le, ki, qi);
            let dist = b.sub(qi, ki);
            let win = b.constant(window as f32, &score_shape);
            let near = b.cmp(CmpOp::Le, dist, win);
            Some(b.cmp(CmpOp::And, causal, near))
        }
        Variant::Alibi => {
            let qi = b.iota(&score_shape, q_ax);
            let ki = b.iota(&score_shape, k_ax);
            // slope(h) = 2^(-8 (h+1) / H) over the flattened head axes.
            // heads live on axes 1 (kv head) and 2 (group).
            let hkv = b.iota(&score_shape, 1);
            let gi = b.iota(&score_shape, 2);
            let g = shape.group() as f32;
            let h1 = b.mul_scalar(hkv, g);
            let h = b.add(h1, gi); // flattened query-head index
            let h = b.add_scalar(h, 1.0);
            let e = b.mul_scalar(h, -8.0 / shape.heads_q as f32);
            let ln2 = std::f32::consts::LN_2;
            let e = b.mul_scalar(e, ln2);
            let slope = b.exp(e); // exp(ln2 * x) == 2^x
            let dist = b.sub(qi, ki);
            let penalty = b.mul(slope, dist);
            s = b.sub(s, penalty);
            Some(b.cmp(CmpOp::Le, ki, qi))
        }
        Variant::Softcap { cap } => {
            let inner = b.mul_scalar(s, 1.0 / cap);
            let t = b.tanh(inner);
            s = b.mul_scalar(t, cap);
            let qi = b.iota(&score_shape, q_ax);
            let ki = b.iota(&score_shape, k_ax);
            Some(b.cmp(CmpOp::Le, ki, qi))
        }
        Variant::PrefixLm { prefix } => {
            let qi = b.iota(&score_shape, q_ax);
            let ki = b.iota(&score_shape, k_ax);
            let causal = b.cmp(CmpOp::Le, ki, qi);
            let p = b.constant(prefix as f32, &score_shape);
            let in_prefix = b.cmp(CmpOp::Lt, ki, p);
            Some(b.cmp(CmpOp::Or, causal, in_prefix))
        }
        Variant::DocumentMask | Variant::Evoformer => {
            // Built by their dedicated constructors (two doc-id
            // orientations / the extra row dimension respectively).
            unreachable!("{} has a dedicated builder", variant.name())
        }
    };
    if let Some(keep) = keep {
        s = b.masked_fill_neg(s, keep);
    }
    let w = b.softmax(s, k_ax);
    b.matmul(w, v)
}

/// Build the full graph for one variant at one shape.
pub fn build(variant: Variant, shape: &AttnShape) -> Graph {
    match variant {
        Variant::DiffAttn { lambda } => build_diff_attn(shape, lambda),
        Variant::Evoformer => build_evoformer(shape),
        Variant::DocumentMask => build_document(shape),
        _ => {
            let mut b = GraphBuilder::new(variant.name());
            let q = b.input("q", &shape.q_shape());
            let k = b.input("k", &shape.kv_shape());
            let v = b.input("v", &shape.kv_shape());
            let o = attention_body(&mut b, q, k, v, shape, variant);
            b.finish(&[o])
        }
    }
}

/// Document masking needs two orientations of the doc-id vector; build it
/// directly (idiomatic code does `doc.view(S,1) == doc.view(1,S)`).
fn build_document(shape: &AttnShape) -> Graph {
    let mut b = GraphBuilder::new("document");
    let q = b.input("q", &shape.q_shape());
    let k = b.input("k", &shape.kv_shape());
    let v = b.input("v", &shape.kv_shape());
    // Two input views of the same doc-id data, as idiomatic code creates
    // with .view(): [B,1,1,S,1] and [B,1,1,1,S].
    let dq = b.input(
        "doc_q",
        &[shape.batch, 1, 1, shape.seq, 1],
    );
    let dk = b.input(
        "doc_k",
        &[shape.batch, 1, 1, 1, shape.seq],
    );
    let scale = 1.0 / (shape.head_dim as f32).sqrt();
    let s0 = b.matmul_nt(q, k);
    let s = b.mul_scalar(s0, scale);
    let score_shape = b.shape(s).clone();
    let dqb = b.broadcast(dq, &score_shape);
    let dkb = b.broadcast(dk, &score_shape);
    let keep = b.cmp(CmpOp::Eq, dqb, dkb);
    let s = b.masked_fill_neg(s, keep);
    let w = b.softmax(s, score_shape.len() - 1);
    let o = b.matmul(w, v);
    b.finish(&[o])
}

/// Differential attention (paper Listing 4): chunk Q/K into two halves,
/// two attentions, subtract the lambda-weighted second.
fn build_diff_attn(shape: &AttnShape, lambda: f32) -> Graph {
    let mut b = GraphBuilder::new("diff_attn");
    // q/k carry 2x heads on the group axis; chunk along it.
    let mut q_shape = shape.q_shape();
    let g_ax = 2;
    q_shape[g_ax] *= 2;
    let q = b.input("q", &q_shape);
    let k = b.input("k", &q_shape);
    let v = b.input("v", &shape.kv_shape());
    let g = shape.group();
    let q0 = b.slice(q, g_ax, 0, g);
    let q1 = b.slice(q, g_ax, g, g);
    let k0 = b.slice(k, g_ax, 0, g);
    let k1 = b.slice(k, g_ax, g, g);
    let a0 = attention_body(&mut b, q0, k0, v, shape, Variant::Vanilla);
    let a1 = attention_body(&mut b, q1, k1, v, shape, Variant::Vanilla);
    let a1s = b.mul_scalar(a1, lambda);
    let o = b.sub(a0, a1s);
    b.finish(&[o])
}

/// Evoformer row-wise gated self-attention (paper §4.3): an extra MSA
/// row dimension R, a pair bias `[B, 1, H, S, S]` broadcast along R
/// (idiomatic code `unsqueeze`s it), and a sigmoid gate on the output.
/// Layout: q/k/v/gate are `[B, R, H, S, D]`.
fn build_evoformer(shape: &AttnShape) -> Graph {
    let mut b = GraphBuilder::new("evoformer");
    let (bs, r, h, s, d) = (
        shape.batch,
        shape.rows.max(1),
        shape.heads_q,
        shape.seq,
        shape.head_dim,
    );
    let qshape = vec![bs, r, h, s, d];
    let q = b.input("q", &qshape);
    let k = b.input("k", &qshape);
    let v = b.input("v", &qshape);
    let bias = b.input("bias", &[bs, 1, h, s, s]);
    let gate = b.input("gate", &qshape);
    let scale = 1.0 / (d as f32).sqrt();
    let s0 = b.matmul_nt(q, k);
    let sc = b.mul_scalar(s0, scale);
    let score_shape = b.shape(sc).clone();
    let biased = {
        let bb = b.broadcast(bias, &score_shape);
        b.add(sc, bb)
    };
    let w = b.softmax(biased, score_shape.len() - 1);
    let a = b.matmul(w, v);
    let gs = b.sigmoid(gate);
    let o = b.mul(gs, a);
    b.finish(&[o])
}

/// Serving-step attention (prefill and incremental decode share one
/// builder): `q_len` query rows `[1*, Hkv, G, q_len, D]` attend over a
/// KV cache `[B, Hkv, 1, S, D]` whose `S` is a *padded bucket*, with two
/// runtime scalar inputs —
///
/// * `kv_len`: the valid cache length (padded columns `ki >= kv_len` are
///   masked out), and
/// * `q_off`: the absolute position of query row 0 (decode passes
///   `kv_len - 1`; whole-prompt prefill passes 0; a chunked-prefill or
///   prefix-reusing chunk passes the chunk's absolute start),
///
/// so one fused plan serves *every* sequence length in a bucket: the
/// shape class, not the exact length, keys the
/// [`PlanCache`](crate::fusion::PlanCache). `shape.seq` is the bucketed
/// KV length; `q_len == 1` builds the decode-step graph over cached K/V.
pub fn build_serving(variant: Variant, shape: &AttnShape, q_len: usize) -> Graph {
    let mut b = GraphBuilder::new(if q_len == 1 {
        "serve_decode"
    } else {
        "serve_prefill"
    });
    let g = shape.group();
    let q = b.input(
        "q",
        &[shape.batch, shape.heads_kv, g, q_len, shape.head_dim],
    );
    let k = b.input("k", &shape.kv_shape());
    let v = b.input("v", &shape.kv_shape());
    let len_in = b.input("kv_len", &[1, 1, 1, 1, 1]);
    let off_in = b.input("q_off", &[1, 1, 1, 1, 1]);
    let scale = 1.0 / (shape.head_dim as f32).sqrt();
    let s0 = b.matmul_nt(q, k);
    let mut s = b.mul_scalar(s0, scale);
    let score_shape = b.shape(s).clone();
    let rank = score_shape.len();
    let (q_ax, k_ax) = (rank - 2, rank - 1);
    if let Variant::Softcap { cap } = variant {
        let inner = b.mul_scalar(s, 1.0 / cap);
        let t = b.tanh(inner);
        s = b.mul_scalar(t, cap);
    }
    let ki = b.iota(&score_shape, k_ax);
    let len_b = b.broadcast(len_in, &score_shape);
    let in_cache = b.cmp(CmpOp::Lt, ki, len_b);
    // Absolute query position = q_off + row index (built lazily: vanilla
    // attention would otherwise leave dead index nodes in the graph).
    let qabs_of = |b: &mut GraphBuilder| {
        let qi = b.iota(&score_shape, q_ax);
        let off_b = b.broadcast(off_in, &score_shape);
        b.add(qi, off_b)
    };
    let keep = match variant {
        Variant::Vanilla => in_cache,
        Variant::Causal | Variant::Softcap { .. } => {
            let qabs = qabs_of(&mut b);
            let causal = b.cmp(CmpOp::Le, ki, qabs);
            b.cmp(CmpOp::And, causal, in_cache)
        }
        Variant::SlidingWindow { window } => {
            let qabs = qabs_of(&mut b);
            let causal = b.cmp(CmpOp::Le, ki, qabs);
            let dist = b.sub(qabs, ki);
            let win = b.constant(window as f32, &score_shape);
            let near = b.cmp(CmpOp::Le, dist, win);
            let cw = b.cmp(CmpOp::And, causal, near);
            b.cmp(CmpOp::And, cw, in_cache)
        }
        Variant::Alibi => {
            let qabs = qabs_of(&mut b);
            // slope(h) = 2^(-8 (h+1) / H) over the flattened head axes,
            // exactly as in the full builder; distances use absolute
            // positions so decode matches the full causal graph.
            let hkv = b.iota(&score_shape, 1);
            let gi = b.iota(&score_shape, 2);
            let h1 = b.mul_scalar(hkv, g as f32);
            let h = b.add(h1, gi);
            let h = b.add_scalar(h, 1.0);
            let e = b.mul_scalar(h, -8.0 / shape.heads_q as f32);
            let e = b.mul_scalar(e, std::f32::consts::LN_2);
            let slope = b.exp(e);
            let dist = b.sub(qabs, ki);
            let penalty = b.mul(slope, dist);
            s = b.sub(s, penalty);
            let causal = b.cmp(CmpOp::Le, ki, qabs);
            b.cmp(CmpOp::And, causal, in_cache)
        }
        other => panic!("variant {} has no serving builder", other.name()),
    };
    let s = b.masked_fill_neg(s, keep);
    let w = b.softmax(s, k_ax);
    let o = b.matmul(w, v);
    b.finish(&[o])
}

/// The variants [`build_serving`] supports (the ones with a serving-arm
/// rewrite of their score mods over runtime `kv_len`/`q_off`): the
/// engine backend's warmup and the chunked-prefill parity tests iterate
/// exactly this set.
pub fn serving_variants() -> Vec<Variant> {
    vec![
        Variant::Vanilla,
        Variant::Causal,
        Variant::Softcap { cap: 20.0 },
        Variant::SlidingWindow { window: 256 },
        Variant::Alibi,
    ]
}

/// All variants at paper-default parameters (window 256, prefix 256,
/// softcap 20, lambda 0.5).
pub fn paper_variants() -> Vec<Variant> {
    vec![
        Variant::Vanilla,
        Variant::Alibi,
        Variant::Softcap { cap: 20.0 },
        Variant::Causal,
        Variant::SlidingWindow { window: 256 },
        Variant::PrefixLm { prefix: 256 },
        Variant::DocumentMask,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{eval, Tensor};
    use std::collections::HashMap;

    pub fn synthetic_inputs(g: &Graph, seed: u64) -> HashMap<String, Tensor> {
        let mut m = HashMap::new();
        for (i, &id) in g.inputs.iter().enumerate() {
            let node = g.node(id);
            let crate::ir::Op::Input { name } = &node.op else {
                unreachable!()
            };
            let t = if name.starts_with("doc") {
                // sorted small doc ids
                let n: usize = node.shape.iter().product();
                let mut v: Vec<f32> = (0..n).map(|j| (j * 3 / n) as f32).collect();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                Tensor::from_vec(&node.shape, v)
            } else {
                Tensor::synthetic(&node.shape, seed + i as u64)
            };
            m.insert(name.clone(), t);
        }
        m
    }

    #[test]
    fn all_variants_build_and_run() {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 4,
            heads_kv: 2,
            seq: 16,
            head_dim: 8,
        };
        let mut variants = paper_variants();
        variants.push(Variant::DiffAttn { lambda: 0.5 });
        variants.push(Variant::Evoformer);
        variants.push(Variant::Rectified { tau: 0.05 });
        for v in variants {
            let v = match v {
                Variant::SlidingWindow { .. } => Variant::SlidingWindow { window: 4 },
                Variant::PrefixLm { .. } => Variant::PrefixLm { prefix: 5 },
                other => other,
            };
            let g = build(v, &shape);
            let inputs = synthetic_inputs(&g, 42);
            let (outs, c) = eval(&g, &inputs);
            assert_eq!(outs.len(), 1, "{}", v.name());
            assert!(
                outs[0].data.iter().all(|x| x.is_finite()),
                "{} produced non-finite values",
                v.name()
            );
            assert!(c.launches > 3, "{}", v.name());
        }
    }

    #[test]
    fn softmax_rows_sum_to_one_under_masking() {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 8,
            head_dim: 4,
        };
        let g = build(Variant::Causal, &shape);
        let inputs = synthetic_inputs(&g, 7);
        let (outs, _) = eval(&g, &inputs);
        // output is convex combination of v rows; magnitudes bounded by v.
        assert!(outs[0].data.iter().all(|x| x.abs() <= 0.5 + 1e-5));
    }

    #[test]
    fn density_properties() {
        assert_eq!(Variant::Vanilla.density(1024), 1.0);
        let c = Variant::Causal.density(1024);
        assert!(c > 0.5 && c < 0.51);
        let w = Variant::SlidingWindow { window: 256 }.density(4096);
        assert!(w < c, "window must be sparser than causal at long seq");
        let p = Variant::PrefixLm { prefix: 256 }.density(4096);
        assert!(p > c, "prefix adds visibility over causal");
    }

    #[test]
    fn serving_graphs_fuse_into_one_pipeline() {
        use crate::fusion::{plan, FusionMode};
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 4,
            heads_kv: 2,
            seq: 64,
            head_dim: 16,
        };
        for v in [
            Variant::Vanilla,
            Variant::Causal,
            Variant::Softcap { cap: 20.0 },
            Variant::SlidingWindow { window: 16 },
            Variant::Alibi,
        ] {
            for q_len in [1, 64] {
                let g = build_serving(v, &shape, q_len);
                let p = plan(&g, FusionMode::Flashlight);
                assert_eq!(
                    p.num_pipelines(),
                    1,
                    "{} q_len={q_len}: {}",
                    v.name(),
                    p.describe(&g)
                );
                assert_eq!(p.groups.len(), 1, "{} q_len={q_len}", v.name());
            }
        }
    }

    #[test]
    fn serving_decode_matches_full_attention_last_row() {
        // A decode step over a *padded* KV bucket with runtime kv_len /
        // q_off must reproduce the last row of the full variant graph —
        // for every serving-supported variant, not just causal (the
        // serving arms rebuild the score mods from runtime positions, so
        // each needs its own numeric parity check).
        let s_real = 24;
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 4,
            heads_kv: 2,
            seq: s_real,
            head_dim: 8,
        };
        for variant in [
            Variant::Causal,
            Variant::Softcap { cap: 20.0 },
            Variant::SlidingWindow { window: 7 },
            Variant::Alibi,
            Variant::Vanilla,
        ] {
            // Vanilla serving attends the whole cache; the full vanilla
            // graph's last row does the same, so it is comparable too.
            let g_full = build(variant, &shape);
            let inputs = synthetic_inputs(&g_full, 3);
            let (full, _) = eval(&g_full, &inputs);

            let padded = AttnShape { seq: 32, ..shape };
            let g_dec = build_serving(variant, &padded, 1);
            let (hkv, grp, d) = (shape.heads_kv, shape.group(), shape.head_dim);
            // q = last row of the full q; k/v zero-padded to the bucket.
            let qf = &inputs["q"]; // [1, hkv, g, s, d]
            let mut qlast = Vec::with_capacity(hkv * grp * d);
            for h in 0..hkv * grp {
                let off = (h * s_real + (s_real - 1)) * d;
                qlast.extend_from_slice(&qf.data[off..off + d]);
            }
            let pad_kv = |t: &Tensor| {
                let mut out = vec![0f32; hkv * 32 * d];
                for h in 0..hkv {
                    let src = h * s_real * d;
                    let dst = h * 32 * d;
                    out[dst..dst + s_real * d]
                        .copy_from_slice(&t.data[src..src + s_real * d]);
                }
                Tensor::from_vec(&[1, hkv, 1, 32, d], out)
            };
            let mut dec_inputs = HashMap::new();
            dec_inputs.insert(
                "q".to_string(),
                Tensor::from_vec(&[1, hkv, grp, 1, d], qlast),
            );
            dec_inputs.insert("k".to_string(), pad_kv(&inputs["k"]));
            dec_inputs.insert("v".to_string(), pad_kv(&inputs["v"]));
            dec_inputs.insert(
                "kv_len".to_string(),
                Tensor::from_vec(&[1, 1, 1, 1, 1], vec![s_real as f32]),
            );
            dec_inputs.insert(
                "q_off".to_string(),
                Tensor::from_vec(&[1, 1, 1, 1, 1], vec![(s_real - 1) as f32]),
            );
            let (dec, _) = eval(&g_dec, &dec_inputs);
            // Compare against row s_real-1 of the full output per head.
            for h in 0..hkv * grp {
                let want = &full[0].data[(h * s_real + (s_real - 1)) * d..][..d];
                let got = &dec[0].data[h * d..(h + 1) * d];
                for (a, b) in want.iter().zip(got) {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "{} head {h}: decode {b} vs full {a}",
                        variant.name()
                    );
                }
            }
        }
    }

    #[test]
    fn serving_prefill_padding_is_inert() {
        // The same prompt through two bucket sizes must agree on the
        // valid rows: padded columns are masked, padded rows ignored.
        let shape64 = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 64,
            head_dim: 8,
        };
        let shape32 = AttnShape { seq: 32, ..shape64 };
        let s_real = 20;
        let d = 8;
        let mk_inputs = |bucket: usize| {
            let mut m = HashMap::new();
            let fill = |seed: u64, rows: usize, bucket: usize| {
                // deterministic values for the first s_real rows, zeros after
                let src = Tensor::synthetic(&[rows, s_real, d], seed);
                let mut out = vec![0f32; rows * bucket * d];
                for h in 0..rows {
                    out[h * bucket * d..h * bucket * d + s_real * d]
                        .copy_from_slice(&src.data[h * s_real * d..(h + 1) * s_real * d]);
                }
                out
            };
            m.insert(
                "q".to_string(),
                Tensor::from_vec(&[1, 2, 1, bucket, d], fill(1, 2, bucket)),
            );
            m.insert(
                "k".to_string(),
                Tensor::from_vec(&[1, 2, 1, bucket, d], fill(2, 2, bucket)),
            );
            m.insert(
                "v".to_string(),
                Tensor::from_vec(&[1, 2, 1, bucket, d], fill(3, 2, bucket)),
            );
            m.insert(
                "kv_len".to_string(),
                Tensor::from_vec(&[1, 1, 1, 1, 1], vec![s_real as f32]),
            );
            m.insert(
                "q_off".to_string(),
                Tensor::from_vec(&[1, 1, 1, 1, 1], vec![0.0]),
            );
            m
        };
        let g32 = build_serving(Variant::Causal, &shape32, 32);
        let g64 = build_serving(Variant::Causal, &shape64, 64);
        let (o32, _) = eval(&g32, &mk_inputs(32));
        let (o64, _) = eval(&g64, &mk_inputs(64));
        for h in 0..2 {
            for r in 0..s_real {
                let a = &o32[0].data[(h * 32 + r) * d..][..d];
                let b = &o64[0].data[(h * 64 + r) * d..][..d];
                for (x, y) in a.iter().zip(b) {
                    assert!((x - y).abs() < 1e-6, "head {h} row {r}: {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn flex_support_classification_matches_paper() {
        assert!(Variant::Causal.flex_supported());
        assert!(Variant::Alibi.flex_supported());
        assert!(!Variant::DiffAttn { lambda: 0.5 }.flex_supported());
        assert!(!Variant::Evoformer.flex_supported());
        // data-dependent masks are outside mask_mod's index-only domain
        assert!(!Variant::Rectified { tau: 0.0 }.flex_supported());
        assert!(Variant::Causal.is_mask_variant());
        assert!(!Variant::Alibi.is_mask_variant());
        assert!(!Variant::Softcap { cap: 20.0 }.is_mask_variant());
    }
}
