//! # flashlight — compiler-native fusion for attention variants
//!
//! A reproduction of *Flashlight: PyTorch Compiler Extensions to
//! Accelerate Attention Variants* (MLSys 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's compiler: a unified-reduction
//!   tensor IR ([`ir`]), computation sketches ([`sketch`]), the fusion
//!   rewrites ([`fusion`]), a tiled executor with HBM traffic counters
//!   ([`exec`]), logical-grid tiling ([`grid`]), a GPU cost model
//!   ([`cost`]), the FlexAttention / FlashInfer / torch.compile baselines
//!   ([`baselines`]), plus the serving coordinator ([`serve`]) and PJRT
//!   runtime ([`runtime`]) that execute AOT-compiled JAX/Pallas artifacts.
//! * **L2 (python/compile)** — JAX attention variants + a tiny LLaMa-style
//!   model, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels)** — the Pallas flash-attention kernel
//!   with fused variant mods (the analog of Flashlight's generated Triton
//!   kernel), `interpret=True` for CPU PJRT execution.
//!
//! Python never runs on the request path: `make artifacts` is build-time.

pub mod analysis;
pub mod baselines;
pub mod bench;
pub mod cost;
pub mod exec;
pub mod fusion;
pub mod grid;
pub mod ir;
pub mod runtime;
pub mod serve;
pub mod sketch;
pub mod tracegen;
pub mod variants;
