//! The paper's compiler passes: structural fusion with dimension demotion
//! (§3.2), semantic fusion via the algebraic online-softmax rewrite
//! (§3.3/3.4), and tiling-aware dimension elimination (§3.5), composed by
//! the planner into kernel-group partitions — plus the serving-side
//! [`PlanCache`] that memoizes plans and tile autotune results per shape
//! class (the FlexAttention compiled-artifact-caching pattern, §4.4).

mod blockmask;
mod cache;
mod online;
mod planner;

pub use blockmask::{
    classify as classify_block_mask, enabled as blockmask_enabled, extract as extract_mask,
    resolve as resolve_blockmask, set_mode_override as set_blockmask_override, BlockMask,
    MaskInfo, MaskKind, TileClass,
};
pub(crate) use blockmask::eval_index_expr;
pub use cache::{
    autotune_tile, autotune_tile_with, bucket_len, CacheStats, CachedPlan, PlanCache, PlanKey,
};
pub use online::{
    online_reduce, online_reduce_blocked, stable_reduce, ExpDiag, ExpHom, ExpReal,
    Mat2, OnlineRowState, Real, Ring,
};
pub use planner::{
    plan, plan_with_threshold, FusionMode, GroupKind, KernelGroup, Pipeline, Plan, RewriteEvent, Rule,
    SoftmaxRoles, TileConfig, FLASHLIGHT_MATERIALIZE_THRESHOLD,
    INDUCTOR_MATERIALIZE_THRESHOLD, MAX_ELIM_DIM,
};
