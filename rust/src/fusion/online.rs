//! Algebraic transformation of reductions (paper §3.3 and Appendix A).
//!
//! The stable-softmax two-pass (max, then shifted exp-sum) is rewritten
//! into the single-pass *online* form. The paper generalizes the rewrite
//! to any ring `(A, ⊕, ⊗)` with a homomorphism `E : A → A` satisfying
//! `E(a ⊕ b) = E(a) ⊗ E(b)` (so `E(0) = 1` and `E(⊖a) = E(a)⁻¹` where
//! inverses exist): the sequences
//!
//! ```text
//! ds[j] = ds[j-1] ⊕ (E(x[j]) ⊗ E(⊖ m[N]))        (stable, needs m[N])
//! do[j] = (do[j-1] ⊗ E(m[j-1] ⊖ m[j])) ⊕ (E(x[j]) ⊗ E(⊖ m[j]))  (online)
//! ```
//!
//! agree at `j = N` because both equal `(⊕_{i≤j} E(x[i])) ⊗ E(⊖ m[j])`.
//! We implement the abstraction faithfully and *prove the theorem by
//! property test* over multiple ring instances (see tests + proptests).

/// A ring `(A, ⊕, ⊗)` as the paper's Appendix A requires. Commutativity
/// of ⊕ is not needed; ⊗ must distribute over ⊕.
pub trait Ring: Copy + PartialEq + std::fmt::Debug {
    fn zero() -> Self; // identity of ⊕
    fn one() -> Self; // identity of ⊗
    fn add(self, other: Self) -> Self; // ⊕
    fn mul(self, other: Self) -> Self; // ⊗
}

/// A homomorphism `E : ℝ → A` mapping (ℝ, +) into (A, ⊗):
/// `E(a + b) = E(a) ⊗ E(b)`.
pub trait ExpHom<A: Ring> {
    fn hom(x: f64) -> A;
}

/// The softmax instance: `A = (ℝ, +, ×)`, `E = exp`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Real(pub f64);

impl Ring for Real {
    fn zero() -> Self {
        Real(0.0)
    }
    fn one() -> Self {
        Real(1.0)
    }
    fn add(self, o: Self) -> Self {
        Real(self.0 + o.0)
    }
    fn mul(self, o: Self) -> Self {
        Real(self.0 * o.0)
    }
}

pub struct ExpReal;
impl ExpHom<Real> for ExpReal {
    fn hom(x: f64) -> Real {
        Real(x.exp())
    }
}

/// A second instance exercising the generality claim: 2×2 upper-
/// triangular matrices over ℝ (a non-commutative ring) with
/// `E(x) = [[e^x, 0], [0, e^{x/2}]]` (diagonal, hence a homomorphism).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Mat2(pub [f64; 4]); // row-major [a b; c d]

impl Ring for Mat2 {
    fn zero() -> Self {
        Mat2([0.0; 4])
    }
    fn one() -> Self {
        Mat2([1.0, 0.0, 0.0, 1.0])
    }
    fn add(self, o: Self) -> Self {
        let a = self.0;
        let b = o.0;
        Mat2([a[0] + b[0], a[1] + b[1], a[2] + b[2], a[3] + b[3]])
    }
    fn mul(self, o: Self) -> Self {
        let a = self.0;
        let b = o.0;
        Mat2([
            a[0] * b[0] + a[1] * b[2],
            a[0] * b[1] + a[1] * b[3],
            a[2] * b[0] + a[3] * b[2],
            a[2] * b[1] + a[3] * b[3],
        ])
    }
}

pub struct ExpDiag;
impl ExpHom<Mat2> for ExpDiag {
    fn hom(x: f64) -> Mat2 {
        Mat2([x.exp(), 0.0, 0.0, (x / 2.0).exp()])
    }
}

/// Stable (two-pass) reduction: `ds[N] = ⊕_j E(x[j] - m[N])` — Alg. 1.
pub fn stable_reduce<A: Ring, E: ExpHom<A>>(x: &[f64]) -> (f64, A) {
    let m = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut d = A::zero();
    for &xi in x {
        d = d.add(E::hom(xi - m));
    }
    (m, d)
}

/// Online (single-pass) reduction — Alg. 2, generalized per Appendix A.
pub fn online_reduce<A: Ring, E: ExpHom<A>>(x: &[f64]) -> (f64, A) {
    let mut m = f64::NEG_INFINITY;
    let mut d = A::zero();
    for &xi in x {
        let m_new = m.max(xi);
        // d ⊗ E(m_old - m_new): rescale the running aggregate, then add
        // the new term. E(-inf - -inf) is guarded: first element sets m.
        let corr = if m.is_finite() {
            E::hom(m - m_new)
        } else {
            A::one()
        };
        d = d.mul(corr).add(E::hom(xi - m_new));
        m = m_new;
    }
    (m, d)
}

/// Blocked online reduction: processes `x` in chunks, carrying (m, d)
/// across blocks — exactly the state the tiled flash kernel maintains.
pub fn online_reduce_blocked<A: Ring, E: ExpHom<A>>(x: &[f64], block: usize) -> (f64, A) {
    let mut m = f64::NEG_INFINITY;
    let mut d = A::zero();
    for chunk in x.chunks(block.max(1)) {
        let bm = chunk.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let m_new = m.max(bm);
        let corr = if m.is_finite() {
            E::hom(m - m_new)
        } else {
            A::one()
        };
        let mut bsum = A::zero();
        for &xi in chunk {
            bsum = bsum.add(E::hom(xi - m_new));
        }
        d = d.mul(corr).add(bsum);
        m = m_new;
    }
    (m, d)
}

/// The concrete per-row online-softmax state the tiled executor keeps in
/// "registers": running max `m`, running denominator `l`, and the running
/// output accumulator `acc` (rescaled by the same correction factor —
/// this is the extension FlashAttention applies to the PV product).
///
/// The update runs on the SIMD kernel tier ([`crate::exec::simd`]):
/// striped-8 row max, the shared vectorized `exp` for the probabilities
/// and the rescale factor, striped-8 block sum for the denominator, and
/// FMA row folds (`axpy`) into the accumulator. Scalar and vector
/// dispatch are bit-identical, so tiling and thread count never
/// perturb the result.
#[derive(Debug, Clone)]
pub struct OnlineRowState {
    pub m: f32,
    pub l: f32,
    pub acc: Vec<f32>,
    /// Per-tile probability scratch (`exp(s - m_new)`), retained across
    /// updates so the k-loop stays allocation-free at steady state.
    p: Vec<f32>,
}

impl OnlineRowState {
    pub fn new(d: usize) -> Self {
        OnlineRowState {
            m: f32::NEG_INFINITY,
            l: 0.0,
            acc: vec![0.0; d],
            p: Vec::new(),
        }
    }

    /// Fold in one kv tile: `scores` (len Bk) and `v_tile` (Bk × d,
    /// row-major). Returns nothing; state carries across tiles.
    pub fn update(&mut self, scores: &[f32], v_tile: &[f32]) {
        use crate::exec::simd;
        let d = self.acc.len();
        debug_assert_eq!(scores.len() * d, v_tile.len());
        let bm = simd::row_max(scores);
        let m_new = if self.m > bm { self.m } else { bm };
        if m_new == f32::NEG_INFINITY {
            return; // all-masked tile
        }
        let alpha = if self.m.is_finite() {
            simd::exp_f32(self.m - m_new)
        } else {
            0.0
        };
        if alpha != 1.0 {
            self.l *= alpha;
            simd::scale(&mut self.acc, alpha);
        }
        // p[j] = exp(s[j] - m_new), vectorized; the block denominator
        // folds through the striped-8 sum. Exact zeros (fully masked
        // positions) skip their PV row fold. vexp_shift overwrites
        // every element, so the scratch only resizes when the tile
        // width changes (steady state: never).
        if self.p.len() != scores.len() {
            self.p.resize(scores.len(), 0.0);
        }
        simd::vexp_shift(&mut self.p, scores, -m_new);
        self.l += simd::row_sum(&self.p);
        for (j, &p) in self.p.iter().enumerate() {
            if p == 0.0 {
                continue;
            }
            simd::axpy(&mut self.acc, p, &v_tile[j * d..(j + 1) * d]);
        }
        self.m = m_new;
    }

    /// Finalize: `acc / l` (zero for fully-masked rows).
    pub fn finish(self) -> Vec<f32> {
        let l = if self.l == 0.0 { 1.0 } else { self.l };
        self.acc.into_iter().map(|a| a / l).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn theorem_ds_equals_do_real() {
        let x = vec![0.3, -1.2, 5.0, 2.2, 5.0, -7.5, 0.0];
        let (ms, Real(ds)) = stable_reduce::<Real, ExpReal>(&x);
        let (mo, Real(d_o)) = online_reduce::<Real, ExpReal>(&x);
        assert_eq!(ms, mo);
        assert!(close(ds, d_o), "{ds} vs {d_o}");
    }

    #[test]
    fn theorem_holds_for_matrix_ring() {
        let x = vec![1.0, 4.0, -2.0, 4.0, 3.5];
        let (_, Mat2(ds)) = stable_reduce::<Mat2, ExpDiag>(&x);
        let (_, Mat2(d_o)) = online_reduce::<Mat2, ExpDiag>(&x);
        for i in 0..4 {
            assert!(close(ds[i], d_o[i]), "{ds:?} vs {d_o:?}");
        }
    }

    #[test]
    fn blocked_matches_elementwise_online() {
        let x: Vec<f64> = (0..37).map(|i| ((i * 13 % 17) as f64) - 8.0).collect();
        let (m1, Real(d1)) = online_reduce::<Real, ExpReal>(&x);
        for block in [1, 2, 3, 8, 37, 64] {
            let (m2, Real(d2)) = online_reduce_blocked::<Real, ExpReal>(&x, block);
            assert_eq!(m1, m2);
            assert!(close(d1, d2));
        }
    }

    #[test]
    fn row_state_matches_two_pass_softmax_times_v() {
        // 1 row, 8 kv positions, d=3; compare acc/l against naive.
        let scores: Vec<f32> = vec![0.5, -1.0, 2.0, 0.0, 1.5, -0.5, 2.0, 3.0];
        let v: Vec<f32> = (0..24).map(|i| (i as f32) * 0.1 - 1.0).collect();
        let mut st = OnlineRowState::new(3);
        for t in 0..4 {
            st.update(&scores[t * 2..t * 2 + 2], &v[t * 6..t * 6 + 6]);
        }
        let out = st.finish();
        // naive
        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let p: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
        let l: f32 = p.iter().sum();
        for dd in 0..3 {
            let want: f32 =
                (0..8).map(|j| p[j] * v[j * 3 + dd]).sum::<f32>() / l;
            assert!((out[dd] - want).abs() < 1e-6, "{out:?}");
        }
    }

    #[test]
    fn all_masked_rows_finish_to_zero() {
        let mut st = OnlineRowState::new(2);
        st.update(&[f32::NEG_INFINITY, f32::NEG_INFINITY], &[1., 2., 3., 4.]);
        let out = st.finish();
        assert_eq!(out, vec![0.0, 0.0]);
    }
}
