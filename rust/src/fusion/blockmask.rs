//! Block-sparse tile classification: FlexAttention-style `BlockMask`.
//!
//! Masked attention variants neutralize dead scores with a `-1e30` fill
//! inside a `Where`; the tiled executor then visits every k-tile and
//! relies on the exact-zero `exp` skip to cancel the dead work. This
//! module recovers the structure *before* execution: the planner hands
//! us the `Where` at the score root, we prove its condition is a pure
//! function of indices (plus optional side inputs like document ids),
//! and we classify every (q-tile, k-tile) cell of the score grid as
//!
//! * `Full`    — every position kept: the executor evaluates the score
//!               subgraph *under* the `Where` directly (no condition
//!               eval, no fill),
//! * `Empty`   — every position masked: the executor skips the tile
//!               outright (no gather, no GEMM, no softmax update),
//! * `Partial` — mixed: the dense masked path runs unchanged.
//!
//! **Bit-identity contract.** Skipping an `Empty` tile must leave the
//! online-softmax state of every row in the q-tile bitwise unchanged
//! relative to the dense path. A dense pass over an all-masked tile
//! performs `m' = max(m, -1e30)`, `alpha = exp(m - m')`, `p = exp(-1e30
//! - m')`: once a row has seen any live position (`m > -1e30`), `m' ==
//! m`, `alpha == 1.0` exactly, and `p` underflows to exactly `0.0`
//! (`simd::exp_f32` pins inputs below its cutoff), so the update is a
//! bitwise no-op. A row with *no* live position anywhere never takes
//! that form — its state replays garbage-cancellation arithmetic the
//! sparse path would have to reproduce — so [`classify`] demotes every
//! `Empty` tile of a q-tile containing a fully-dead row to `Partial`.
//! With that demotion, sparse execution is *unconditionally* bit-
//! identical to dense.
//!
//! **Data-dependent masks.** `Variant::Rectified`-style thresholding
//! (`keep = score >= tau`) cannot be classified statically; [`extract`]
//! reports it as [`MaskKind::Threshold`] and the executor prunes at
//! runtime: it evaluates the raw score tile (a coarse first pass over
//! the exact scores), and skips the softmax/PV work when the tile
//! maximum falls below `tau` *and* every row is already live — the same
//! no-op proof as above, decided per tile from the data.
//!
//! `FLASHLIGHT_BLOCKMASK=0|off` disables the whole layer (dense
//! fallback), resolved once per process like `FLASHLIGHT_SIMD`; tests
//! and benches flip a thread-local override for in-process A/B runs.

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::OnceLock;

use crate::exec::{eval_pw, Tensor, NEG_INF};
use crate::ir::{CmpOp, Graph, NodeId, Op, PwOp};

/// Deepest score rank the classifier's fixed-size coordinate buffers
/// support (attention scores are rank 5; headroom for exotic variants).
const MAX_RANK: usize = 8;

/// Predicate evaluations (`n_dep_combos * sq * sk`) past which
/// classification falls back to dense — keeps plan-time cost bounded on
/// pathological shapes.
const CLASSIFY_CELL_CAP: usize = 1 << 26;

/// Class of one (q-tile, k-tile) cell of the score grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileClass {
    /// Every position kept: elide the mask/fill ops.
    Full,
    /// Mixed: run the dense masked path.
    Partial,
    /// Every position masked: skip the tile outright.
    Empty,
}

/// How the mask decides which positions live.
#[derive(Debug, Clone, PartialEq)]
pub enum MaskKind {
    /// Pure function of indices plus the named side inputs (empty for
    /// causal/sliding-window/prefix-LM; document ids / serving lengths
    /// otherwise). Classifiable whenever those inputs are at hand.
    Index { input_deps: Vec<String> },
    /// `keep = score >= tau`: data-dependent, prunable only at runtime
    /// from the scores themselves.
    Threshold { tau: f32 },
}

/// A score-root `Where` the planner proved maskable: `cond` selects
/// live positions, `value` is the unmasked score subgraph, the fill is
/// the `-1e30` sentinel.
#[derive(Debug, Clone, PartialEq)]
pub struct MaskInfo {
    pub cond: NodeId,
    pub value: NodeId,
    pub kind: MaskKind,
}

impl MaskInfo {
    /// True when the predicate needs no runtime inputs at all — the
    /// plan cache can classify it once per shape bucket.
    pub fn is_input_free(&self) -> bool {
        matches!(&self.kind, MaskKind::Index { input_deps } if input_deps.is_empty())
    }
}

/// Tile classes for one score grid, per combination of the "dep" axes
/// (axes besides q/kv the predicate varies along — e.g. batch for
/// document masks; empty for index-only templates).
#[derive(Debug, Clone)]
pub struct BlockMask {
    pub block_q: usize,
    pub block_k: usize,
    pub sq: usize,
    pub sk: usize,
    pub n_q_tiles: usize,
    pub n_k_tiles: usize,
    /// Score-space axes the predicate varies along besides q/kv.
    pub dep_axes: Vec<usize>,
    dep_sizes: Vec<usize>,
    /// `[(dep * n_q_tiles + qt) * n_k_tiles + kt]`.
    classes: Vec<TileClass>,
}

impl BlockMask {
    pub fn n_deps(&self) -> usize {
        self.dep_sizes.iter().product::<usize>().max(1)
    }

    pub fn class(&self, dep: usize, qt: usize, kt: usize) -> TileClass {
        self.classes[(dep * self.n_q_tiles + qt) * self.n_k_tiles + kt]
    }

    /// Overwrite one tile class. Test/fault-injection hook for the
    /// static verifier (`analysis::verify_block_mask`) — never called
    /// by the planner or executor.
    #[doc(hidden)]
    pub fn override_class(&mut self, dep: usize, qt: usize, kt: usize, class: TileClass) {
        self.classes[(dep * self.n_q_tiles + qt) * self.n_k_tiles + kt] = class;
    }

    /// Dep-combination index of a block whose score-space region starts
    /// are `region[ax].0` (grid outer axes carry tile size 1, so the
    /// start *is* the coordinate).
    pub fn dep_index(&self, region: &[(usize, usize)]) -> usize {
        let mut idx = 0usize;
        for (i, &ax) in self.dep_axes.iter().enumerate() {
            idx = idx * self.dep_sizes[i] + region[ax].0.min(self.dep_sizes[i] - 1);
        }
        idx
    }

    fn ck(&self, kt: usize) -> usize {
        self.block_k.min(self.sk - kt * self.block_k)
    }

    /// Live (non-`Empty`) k elements for one q-tile row of one dep
    /// combination — the executor's per-block work estimate.
    pub fn live_k_elems(&self, dep: usize, qt: usize) -> usize {
        (0..self.n_k_tiles)
            .filter(|&kt| self.class(dep, qt, kt) != TileClass::Empty)
            .map(|kt| self.ck(kt))
            .sum()
    }

    /// Sum of [`Self::live_k_elems`] over every (dep, q-tile) row: what
    /// the analytic traffic model charges K/V re-reads against instead
    /// of `n_q_tiles * sk`.
    pub fn visited_k_elems(&self) -> u64 {
        let mut total = 0u64;
        for dep in 0..self.n_deps() {
            for qt in 0..self.n_q_tiles {
                total += self.live_k_elems(dep, qt) as u64;
            }
        }
        total
    }

    /// K elements belonging to k-tiles live for *some* (dep, q-tile) —
    /// the compulsory first-touch footprint of the K/V operands.
    pub fn touched_k_elems(&self) -> usize {
        (0..self.n_k_tiles)
            .filter(|&kt| {
                (0..self.n_deps()).any(|dep| {
                    (0..self.n_q_tiles).any(|qt| self.class(dep, qt, kt) != TileClass::Empty)
                })
            })
            .map(|kt| self.ck(kt))
            .sum()
    }

    /// Number of `Empty` cells across every dep combination.
    pub fn skipped_tiles(&self) -> u64 {
        self.classes.iter().filter(|&&c| c == TileClass::Empty).count() as u64
    }
}

/// Strip explicit `Broadcast` wrappers (the graph builder inserts them
/// whenever operand shapes differ).
fn peel_broadcast(g: &Graph, mut id: NodeId) -> NodeId {
    while let Op::Broadcast { input } = &g.node(id).op {
        id = *input;
    }
    id
}

/// True iff the subgraph under `id` is a pure function of indices,
/// constants, and external inputs (collected into `deps`) — no matmul
/// or reduction, so a scalar interpreter can evaluate it per position.
fn index_only(g: &Graph, id: NodeId, deps: &mut Vec<String>) -> bool {
    match &g.node(id).op {
        Op::Const { .. } | Op::Iota { .. } => true,
        Op::Input { name } => {
            deps.push(name.clone());
            true
        }
        Op::Broadcast { input } | Op::Slice { input, .. } => index_only(g, *input, deps),
        Op::Pointwise { inputs, .. } => inputs.iter().all(|&i| index_only(g, i, deps)),
        Op::Matmul { .. } | Op::Reduce { .. } => false,
    }
}

/// Mark which axes the value of `id` can vary along. Conservative: an
/// unknown construct marks every axis (more dep combinations scanned,
/// never a wrong share).
fn varies_along(g: &Graph, id: NodeId, axes: &mut [bool]) {
    let node = g.node(id);
    match &node.op {
        Op::Const { .. } => {}
        Op::Iota { axis } => {
            if *axis < axes.len() {
                axes[*axis] = true;
            }
        }
        Op::Input { .. } => {
            for (ax, &sz) in node.shape.iter().enumerate() {
                if sz > 1 && ax < axes.len() {
                    axes[ax] = true;
                }
            }
        }
        Op::Broadcast { input } | Op::Slice { input, .. } => varies_along(g, *input, axes),
        Op::Pointwise { inputs, .. } => {
            for &i in inputs {
                varies_along(g, i, axes);
            }
        }
        Op::Matmul { .. } | Op::Reduce { .. } => {
            for a in axes.iter_mut() {
                *a = true;
            }
        }
    }
}

/// Evaluate an index-only predicate subgraph at one score coordinate.
pub(crate) fn eval_index_expr(
    g: &Graph,
    id: NodeId,
    coords: &[usize],
    inputs: &HashMap<String, Tensor>,
) -> f32 {
    let node = g.node(id);
    match &node.op {
        Op::Const { value } => *value,
        Op::Iota { axis } => coords[*axis] as f32,
        Op::Input { name } => inputs[name].at_broadcast(coords),
        Op::Broadcast { input } => {
            let child = g.node(*input);
            let mut c = [0usize; MAX_RANK];
            c[..coords.len()].copy_from_slice(coords);
            for (ax, &sz) in child.shape.iter().enumerate() {
                if sz == 1 {
                    c[ax] = 0;
                }
            }
            eval_index_expr(g, *input, &c[..coords.len()], inputs)
        }
        Op::Slice { input, axis, start, .. } => {
            let mut c = [0usize; MAX_RANK];
            c[..coords.len()].copy_from_slice(coords);
            c[*axis] += *start;
            eval_index_expr(g, *input, &c[..coords.len()], inputs)
        }
        Op::Pointwise { op, inputs: pins } => {
            let mut args = [0f32; 3];
            for (k, &i) in pins.iter().enumerate() {
                args[k] = eval_index_expr(g, i, coords, inputs);
            }
            eval_pw(*op, &args[..pins.len()])
        }
        Op::Matmul { .. } | Op::Reduce { .. } => {
            unreachable!("index-only predicates never contain matmul/reduce")
        }
    }
}

/// Recognize a maskable score root: `Where(cond, value, -1e30)` whose
/// condition is either index-only ([`MaskKind::Index`]) or a `score >=
/// tau` threshold on the value itself ([`MaskKind::Threshold`]).
/// Anything else (including fills other than the `-1e30` sentinel, for
/// which the skip proof does not hold) returns `None` — dense path.
pub fn extract(g: &Graph, score_root: NodeId) -> Option<MaskInfo> {
    let Op::Pointwise { op: PwOp::Where, inputs } = &g.node(score_root).op else {
        return None;
    };
    let (cond, value, fill) = (inputs[0], inputs[1], inputs[2]);
    match g.node(peel_broadcast(g, fill)).op {
        Op::Const { value: f } if f == NEG_INF => {}
        _ => return None,
    }
    let cond_src = peel_broadcast(g, cond);
    // Threshold check first: `Ge(score, tau)` would otherwise fail the
    // index walk at its matmul and lose the runtime-prunable kind.
    if let Op::Pointwise { op: PwOp::Cmp(CmpOp::Ge), inputs: cins } = &g.node(cond_src).op {
        if peel_broadcast(g, cins[0]) == peel_broadcast(g, value) {
            if let Op::Const { value: tau } = g.node(peel_broadcast(g, cins[1])).op {
                return Some(MaskInfo {
                    cond,
                    value,
                    kind: MaskKind::Threshold { tau },
                });
            }
        }
    }
    let mut deps = Vec::new();
    if index_only(g, cond_src, &mut deps) {
        deps.sort();
        deps.dedup();
        return Some(MaskInfo {
            cond,
            value,
            kind: MaskKind::Index { input_deps: deps },
        });
    }
    None
}

/// Classify every (q-tile, k-tile) cell of the score grid under an
/// index mask by brute-force evaluation of the predicate, with the
/// fully-dead-row demotion described in the module docs. `None` when
/// the mask is data-dependent, a named side input is missing from
/// `inputs`, or the scan would exceed [`CLASSIFY_CELL_CAP`].
#[allow(clippy::too_many_arguments)]
pub fn classify(
    g: &Graph,
    info: &MaskInfo,
    score_shape: &[usize],
    q_ax: usize,
    kv_ax: usize,
    block_q: usize,
    block_k: usize,
    inputs: &HashMap<String, Tensor>,
) -> Option<BlockMask> {
    let MaskKind::Index { input_deps } = &info.kind else {
        return None;
    };
    if !input_deps.iter().all(|n| inputs.contains_key(n)) {
        return None;
    }
    let rank = score_shape.len();
    if rank > MAX_RANK || q_ax >= rank || kv_ax >= rank || q_ax == kv_ax {
        return None;
    }
    let (sq, sk) = (score_shape[q_ax], score_shape[kv_ax]);
    if sq == 0 || sk == 0 {
        return None;
    }
    let bq = block_q.max(1).min(sq);
    let bk = block_k.max(1).min(sk);
    let (n_q, n_k) = (sq.div_ceil(bq), sk.div_ceil(bk));

    let mut varies = [false; MAX_RANK];
    varies_along(g, info.cond, &mut varies[..rank]);
    let mut dep_axes = Vec::new();
    let mut dep_sizes = Vec::new();
    for (ax, &sz) in score_shape.iter().enumerate() {
        if ax != q_ax && ax != kv_ax && varies[ax] && sz > 1 {
            dep_axes.push(ax);
            dep_sizes.push(sz);
        }
    }
    let n_dep = dep_sizes.iter().product::<usize>().max(1);
    if n_dep.saturating_mul(sq).saturating_mul(sk) > CLASSIFY_CELL_CAP {
        return None;
    }

    let mut classes = vec![TileClass::Partial; n_dep * n_q * n_k];
    let mut kept = vec![0u32; n_q * n_k];
    let mut row_live = vec![false; sq];
    let mut coords = [0usize; MAX_RANK];
    for dep in 0..n_dep {
        let mut rem = dep;
        for i in (0..dep_axes.len()).rev() {
            coords[dep_axes[i]] = rem % dep_sizes[i];
            rem /= dep_sizes[i];
        }
        kept.iter_mut().for_each(|c| *c = 0);
        row_live.iter_mut().for_each(|r| *r = false);
        for qi in 0..sq {
            coords[q_ax] = qi;
            for ki in 0..sk {
                coords[kv_ax] = ki;
                if eval_index_expr(g, info.cond, &coords[..rank], inputs) != 0.0 {
                    kept[(qi / bq) * n_k + ki / bk] += 1;
                    row_live[qi] = true;
                }
            }
        }
        for qt in 0..n_q {
            let cq = bq.min(sq - qt * bq);
            // A q-tile holding a row with no live key anywhere must
            // replay the dense garbage-cancellation arithmetic exactly:
            // demote its Empty tiles to Partial (see module docs).
            let has_dead_row = (qt * bq..qt * bq + cq).any(|q| !row_live[q]);
            for kt in 0..n_k {
                let ck = bk.min(sk - kt * bk);
                let n = kept[qt * n_k + kt];
                classes[(dep * n_q + qt) * n_k + kt] = if n == (cq * ck) as u32 {
                    TileClass::Full
                } else if n == 0 && !has_dead_row {
                    TileClass::Empty
                } else {
                    TileClass::Partial
                };
            }
        }
    }
    Some(BlockMask {
        block_q: bq,
        block_k: bk,
        sq,
        sk,
        n_q_tiles: n_q,
        n_k_tiles: n_k,
        dep_axes,
        dep_sizes,
        classes,
    })
}

// ---------------------------------------------------------------------
// FLASHLIGHT_BLOCKMASK kill switch + in-process override
// ---------------------------------------------------------------------

/// Parse a `FLASHLIGHT_BLOCKMASK` value: `0`/`off` disable the block-
/// sparse layer; anything else (including unset) leaves it on.
pub fn resolve(env: Option<&str>) -> bool {
    !matches!(env.map(str::trim), Some("0") | Some("off"))
}

static ENABLED: OnceLock<bool> = OnceLock::new();

thread_local! {
    /// 0 = follow the env var, 1 = force dense, 2 = force sparse.
    /// Thread-local (not process-global): `enabled()` is only consulted
    /// on the scheduling thread (plan counters / run setup), so tests
    /// and benches can A/B dense-vs-sparse without racing the parallel
    /// test harness.
    static OVERRIDE: Cell<u8> = const { Cell::new(0) };
}

/// Force the block-mask layer on (`Some(true)`), off (`Some(false)`),
/// or back to the env-var default (`None`) for the calling thread —
/// the in-process A/B hook used by the bit-identity gates and the
/// sparsity sweep bench.
pub fn set_mode_override(mode: Option<bool>) {
    OVERRIDE.with(|c| {
        c.set(match mode {
            None => 0,
            Some(false) => 1,
            Some(true) => 2,
        })
    });
}

/// Whether block-sparse planning/execution is active, honoring the
/// thread-local override first and `FLASHLIGHT_BLOCKMASK` (resolved
/// once per process) otherwise.
pub fn enabled() -> bool {
    match OVERRIDE.with(|c| c.get()) {
        1 => false,
        2 => true,
        _ => *ENABLED
            .get_or_init(|| resolve(std::env::var("FLASHLIGHT_BLOCKMASK").ok().as_deref())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::variants::{build, AttnShape, Variant};

    fn shape(seq: usize) -> AttnShape {
        AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 1,
            seq,
            head_dim: 8,
        }
    }

    /// The unique maskable `Where` in a variant graph.
    fn mask_root(g: &Graph) -> (NodeId, MaskInfo) {
        for id in g.ids() {
            if let Some(info) = extract(g, id) {
                return (id, info);
            }
        }
        panic!("graph has no maskable score root");
    }

    #[test]
    fn resolve_parses_kill_switch() {
        assert!(resolve(None));
        assert!(resolve(Some("1")));
        assert!(resolve(Some("on")));
        assert!(resolve(Some("anything")));
        assert!(!resolve(Some("0")));
        assert!(!resolve(Some("off")));
        assert!(!resolve(Some(" off ")));
    }

    #[test]
    fn override_wins_over_default_on_this_thread() {
        set_mode_override(Some(false));
        assert!(!enabled());
        set_mode_override(Some(true));
        assert!(enabled());
        set_mode_override(None);
    }

    #[test]
    fn causal_extracts_as_input_free_index_mask() {
        let g = build(Variant::Causal, &shape(32));
        let (_, info) = mask_root(&g);
        assert!(info.is_input_free(), "{:?}", info.kind);
    }

    #[test]
    fn document_mask_depends_on_doc_inputs() {
        let g = build(Variant::DocumentMask, &shape(32));
        let (_, info) = mask_root(&g);
        match &info.kind {
            MaskKind::Index { input_deps } => {
                assert!(!input_deps.is_empty(), "document mask must name its id inputs");
            }
            other => panic!("expected index mask, got {other:?}"),
        }
    }

    #[test]
    fn rectified_extracts_as_runtime_threshold() {
        let g = build(Variant::Rectified { tau: 0.05 }, &shape(32));
        let (_, info) = mask_root(&g);
        match info.kind {
            MaskKind::Threshold { tau } => assert_eq!(tau, 0.05),
            other => panic!("expected threshold mask, got {other:?}"),
        }
    }

    #[test]
    fn sliding_window_classification_matches_brute_force() {
        let (seq, window, b) = (23usize, 5usize, 8usize);
        let g = build(Variant::SlidingWindow { window }, &shape(seq));
        let (root, info) = mask_root(&g);
        let score_shape = g.node(root).shape.clone();
        let rank = score_shape.len();
        let bm = classify(
            &g,
            &info,
            &score_shape,
            rank - 2,
            rank - 1,
            b,
            b,
            &HashMap::new(),
        )
        .expect("index mask must classify");
        assert!(bm.dep_axes.is_empty());
        let keep = |qi: usize, ki: usize| ki <= qi && qi - ki <= window;
        for qt in 0..bm.n_q_tiles {
            let cq = b.min(seq - qt * b);
            for kt in 0..bm.n_k_tiles {
                let ck = b.min(seq - kt * b);
                let kept = (0..cq)
                    .flat_map(|r| (0..ck).map(move |c| (qt * b + r, kt * b + c)))
                    .filter(|&(qi, ki)| keep(qi, ki))
                    .count();
                let want = if kept == cq * ck {
                    TileClass::Full
                } else if kept == 0 {
                    TileClass::Empty
                } else {
                    TileClass::Partial
                };
                assert_eq!(bm.class(0, qt, kt), want, "tile ({qt},{kt})");
            }
        }
        assert!(bm.skipped_tiles() > 0, "window 5 over seq 23 must skip tiles");
        assert!((bm.visited_k_elems() as usize) < bm.n_q_tiles * seq);
    }

    #[test]
    fn dead_rows_demote_empty_to_partial() {
        // Document mask where the ids never match: every row is dead, so
        // no tile may be skipped (the dense arithmetic must replay).
        let seq = 16usize;
        let g = build(Variant::DocumentMask, &shape(seq));
        let (root, info) = mask_root(&g);
        let score_shape = g.node(root).shape.clone();
        let rank = score_shape.len();
        let MaskKind::Index { input_deps } = &info.kind else {
            panic!("document mask must be an index mask")
        };
        let mut inputs = HashMap::new();
        for (i, name) in input_deps.iter().enumerate() {
            // Disjoint id ranges: doc ids on the q side never equal the
            // k side, so keep is false everywhere.
            let node = g
                .inputs
                .iter()
                .map(|&id| g.node(id))
                .find(|n| matches!(&n.op, Op::Input { name: q } if q == name))
                .expect("dep input must exist");
            let n: usize = node.shape.iter().product();
            inputs.insert(
                name.clone(),
                Tensor::from_vec(&node.shape, vec![(i * 1000) as f32; n]),
            );
        }
        let bm = classify(&g, &info, &score_shape, rank - 2, rank - 1, 8, 8, &inputs)
            .expect("document mask with ids present must classify");
        assert_eq!(bm.skipped_tiles(), 0, "dead rows must force Partial");
        assert!(bm
            .classes
            .iter()
            .all(|&c| c == TileClass::Partial));
    }

    #[test]
    fn full_tiles_and_counters_on_causal() {
        let (seq, b) = (32usize, 8usize);
        let g = build(Variant::Causal, &shape(seq));
        let (root, info) = mask_root(&g);
        let score_shape = g.node(root).shape.clone();
        let rank = score_shape.len();
        let bm = classify(&g, &info, &score_shape, rank - 2, rank - 1, b, b, &HashMap::new())
            .unwrap();
        // Below-diagonal tiles Full, diagonal Partial, above Empty.
        for qt in 0..bm.n_q_tiles {
            for kt in 0..bm.n_k_tiles {
                let want = if kt < qt {
                    TileClass::Full
                } else if kt == qt {
                    TileClass::Partial
                } else {
                    TileClass::Empty
                };
                assert_eq!(bm.class(0, qt, kt), want);
            }
        }
        // Every k-tile is live for its diagonal q-tile: compulsory
        // footprint stays the whole K axis, only re-reads shrink.
        assert_eq!(bm.touched_k_elems(), seq);
        assert_eq!(bm.visited_k_elems(), (8 + 16 + 24 + 32) as u64);
        assert_eq!(bm.skipped_tiles(), 6);
    }
}
