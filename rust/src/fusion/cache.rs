//! Plan cache: memoized `plan()` + tile-autotune results, keyed by shape
//! class, shared across serving steps.
//!
//! FlexAttention's serving win (paper §4.4) comes from caching compiled
//! artifacts across calls with identical shapes; the same pattern applies
//! to Flashlight's fusion plans. Serving traffic produces a small number
//! of *shape classes* — sequence lengths bucketed to KV-page multiples —
//! and every decode step of every request in a bucket can reuse one
//! immutable `Arc<CachedPlan>` (graph + plan + autotuned tile schedule).
//! Planning happens once per class; steady-state decode is a pure cache
//! hit (asserted > 90% by the serve tests).
//!
//! The cache is LRU-bounded and keeps hit/miss counters that the serving
//! layer surfaces in its metrics.

use std::collections::HashMap;
use std::sync::Arc;

use crate::ir::{Graph, NodeId};
use crate::sketch::{analyze, DimAnalysis};

use super::blockmask::{self, BlockMask};
use super::planner::{plan, FusionMode, GroupKind, Plan, TileConfig};

/// Round `n` up to a multiple of `granule` (at least one granule) — the
/// shape-class bucketing for sequence lengths. Buckets are what make the
/// cache hit: with the serving path's 64-token granule, a request at
/// context 130 and one at context 180 share the 192-bucket plan, with
/// the runtime `kv_len` input masking the padding.
pub fn bucket_len(n: usize, granule: usize) -> usize {
    let g = granule.max(1);
    n.max(1).div_ceil(g) * g
}

/// Identity of a shape class: everything the plan depends on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Role of the graph ("prefill" / "decode" / caller-defined).
    pub tag: &'static str,
    /// Variant name (from [`crate::variants::Variant::name`]).
    pub variant: &'static str,
    pub heads_q: usize,
    pub heads_kv: usize,
    pub head_dim: usize,
    /// Bucketed query length.
    pub q_len: usize,
    /// Bucketed KV length.
    pub kv_len: usize,
}

/// One immutable cache entry: the graph, its fusion plan, the tile
/// schedule the autotuner picked, and the executor-side graph metadata
/// (dimension analysis + consumer lists) that
/// [`crate::exec::execute_plans_batched`] would otherwise recompute for
/// every job of every call. Shared by `Arc` so concurrent decode steps
/// of many requests reuse one plan without copies.
///
/// A `CachedPlan` is **runner-agnostic**: pure data describing *what*
/// the fused plan computes — no execution machinery, no thread pool, no
/// device handles. *Who* runs it is a [`crate::exec::PlanRunner`]
/// ([`crate::exec::CpuRunner`] today, a PJRT path later); building a
/// plan needs no runner at all (autotune scores candidate tiles with
/// the analytical cost model, it never executes), which is why one
/// plan cache can be rebuilt identically inside every shard of a
/// multi-instance deployment and why a shard's cache dies with its
/// instance without invalidating anything anywhere else.
pub struct CachedPlan {
    pub graph: Graph,
    pub plan: Plan,
    pub tile: TileConfig,
    /// Dimension analysis of `graph`, computed once at build time —
    /// hand it to [`crate::exec::PlanJob::analysis`] so per-step
    /// execution performs zero `analyze()` calls.
    pub analysis: DimAnalysis,
    /// `graph.consumers()`, computed once at build time (the batched
    /// executor's single-kernel path needs it per job).
    pub consumers: Vec<Vec<NodeId>>,
    /// Block-sparse tile classes per plan group, classified once per
    /// shape class from the plan's *input-free* index mask predicate
    /// with the autotuned tile. `None` slots (unmasked groups, runtime-
    /// dependent masks such as document ids) fall back to per-launch
    /// classification in the executor.
    pub block_masks: Vec<Option<Arc<BlockMask>>>,
}

/// Classify each pipeline group's static block mask (see
/// [`CachedPlan::block_masks`]). Cheap relative to planning and always
/// computed, so cache entries are valid under either blockmask mode.
fn build_block_masks(
    g: &Graph,
    p: &Plan,
    an: &DimAnalysis,
    tile: TileConfig,
) -> Vec<Option<Arc<BlockMask>>> {
    p.groups
        .iter()
        .map(|grp| {
            let GroupKind::Pipeline(pipe) = &grp.kind else {
                return None;
            };
            if pipe.softmax.is_none() {
                return None;
            }
            let info = pipe.mask.as_ref()?;
            if !info.is_input_free() {
                return None;
            }
            let score_shape = &g.node(pipe.score_root).shape;
            let score_axes = &an.axes[pipe.score_root.0 as usize];
            let kv_ax = score_axes.iter().rposition(|c| *c == pipe.kv_class)?;
            let q_ax = score_axes[..kv_ax]
                .iter()
                .rposition(|c| *c == pipe.q_class)?;
            blockmask::classify(
                g,
                info,
                score_shape,
                q_ax,
                kv_ax,
                tile.block_q.min(score_shape[q_ax]),
                tile.block_k.min(score_shape[kv_ax]),
                &HashMap::new(),
            )
            .map(Arc::new)
        })
        .collect()
}

/// Hit/miss counters, surfaced in serving metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub evictions: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction in [0, 1] (0 when never queried).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// LRU-bounded memo of fusion plans.
pub struct PlanCache {
    capacity: usize,
    /// key -> (last-use tick, entry)
    map: HashMap<PlanKey, (u64, Arc<CachedPlan>)>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    /// When set, autotune only considers tile schedules with this
    /// `block_k`. The serving path pins it to the KV page granule so the
    /// kv-tiling — and therefore the online-softmax rescale points — is
    /// identical across every plan in the cache, which is what makes
    /// chunked prefill bit-identical to one-shot prefill (per-row online
    /// state only depends on the kv tile boundaries, never on `block_q`).
    fixed_block_k: Option<usize>,
}

/// Candidate tile schedules searched by [`autotune_tile`].
const TILE_CANDIDATES: &[(usize, usize)] = &[
    (32, 32),
    (32, 64),
    (64, 32),
    (64, 64),
    (64, 128),
    (128, 64),
    (128, 128),
];

/// Pick the tile schedule minimizing the plan's modeled data movement
/// (HBM + L2) with launch count as tie-breaker. Deterministic: candidates
/// are scanned in a fixed order and strict improvement is required.
pub fn autotune_tile(g: &Graph, p: &Plan) -> TileConfig {
    autotune_tile_with(g, p, None)
}

/// [`autotune_tile`] restricted to candidates whose `block_k` equals
/// `fixed_block_k` (when set). Falls back to a default-shaped tile with
/// the pinned `block_k` if no candidate matches.
pub fn autotune_tile_with(g: &Graph, p: &Plan, fixed_block_k: Option<usize>) -> TileConfig {
    let mut best = TileConfig {
        block_k: fixed_block_k.unwrap_or(TileConfig::default().block_k),
        ..TileConfig::default()
    };
    let mut best_cost = u64::MAX;
    for &(bq, bk) in TILE_CANDIDATES {
        if fixed_block_k.is_some_and(|f| f != bk) {
            continue;
        }
        let tile = TileConfig {
            block_q: bq,
            block_k: bk,
            ..TileConfig::default()
        };
        let c = p.counters(g, tile);
        let cost = c.total_with_l2();
        if cost < best_cost {
            best_cost = cost;
            best = tile;
        }
    }
    best
}

impl PlanCache {
    pub fn new(capacity: usize) -> Self {
        PlanCache {
            capacity: capacity.max(1),
            map: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            fixed_block_k: None,
        }
    }

    /// A cache whose autotune is pinned to `block_k` (the serving path
    /// pins the KV page granule — see [`PlanCache::fixed_block_k`]).
    pub fn with_block_k(capacity: usize, block_k: usize) -> Self {
        PlanCache {
            fixed_block_k: Some(block_k.max(1)),
            ..PlanCache::new(capacity)
        }
    }

    /// Look up the plan for `key`, building (plan + tile autotune) on a
    /// miss via `build_graph`. Returns a shared handle; the entry stays
    /// cached until LRU eviction.
    pub fn get_or_build(
        &mut self,
        key: PlanKey,
        build_graph: impl FnOnce() -> Graph,
    ) -> Arc<CachedPlan> {
        self.tick += 1;
        if let Some((t, e)) = self.map.get_mut(&key) {
            *t = self.tick;
            self.hits += 1;
            return e.clone();
        }
        self.misses += 1;
        let graph = build_graph();
        let p = plan(&graph, FusionMode::Flashlight);
        let tile = autotune_tile_with(&graph, &p, self.fixed_block_k);
        let analysis = analyze(&graph);
        let consumers = graph.consumers();
        let block_masks = build_block_masks(&graph, &p, &analysis, tile);
        let entry = Arc::new(CachedPlan {
            graph,
            plan: p,
            tile,
            analysis,
            consumers,
            block_masks,
        });
        // Static verification at plan birth: only here, on the miss
        // path, so steady-state serving (all hits) does zero verify
        // work — the cost is amortized per shape bucket exactly like
        // planning itself.
        match crate::analysis::verify_mode() {
            crate::analysis::VerifyMode::Off => {}
            mode => {
                if let Err(diags) = crate::analysis::verify_cached(&entry) {
                    let mut report = String::new();
                    for d in &diags {
                        report.push_str(&d.to_string());
                        report.push('\n');
                    }
                    if mode == crate::analysis::VerifyMode::Strict {
                        panic!("plan verification failed for {key:?}:\n{report}");
                    }
                    eprintln!("flashlight: plan verification failed for {key:?}:\n{report}");
                }
            }
        }
        if self.map.len() >= self.capacity {
            // Evict the least-recently-used entry.
            let victim: Option<PlanKey> = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone());
            if let Some(victim) = victim {
                self.map.remove(&victim);
                self.evictions += 1;
            }
        }
        self.map.insert(key, (self.tick, entry.clone()));
        entry
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            entries: self.map.len(),
            evictions: self.evictions,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::variants::{build_serving, AttnShape, Variant};

    fn shape(kv: usize) -> AttnShape {
        AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 4,
            heads_kv: 2,
            seq: kv,
            head_dim: 16,
        }
    }

    fn key(kv_bucket: usize) -> PlanKey {
        PlanKey {
            tag: "decode",
            variant: Variant::Causal.name(),
            heads_q: 4,
            heads_kv: 2,
            head_dim: 16,
            q_len: 1,
            kv_len: kv_bucket,
        }
    }

    #[test]
    fn bucketing_rounds_up_to_granule() {
        assert_eq!(bucket_len(1, 64), 64);
        assert_eq!(bucket_len(64, 64), 64);
        assert_eq!(bucket_len(65, 64), 128);
        assert_eq!(bucket_len(0, 64), 64);
        assert_eq!(bucket_len(300, 128), 384);
    }

    #[test]
    fn same_shape_bucket_hits() {
        let mut c = PlanCache::new(8);
        // contexts 100 and 120 both bucket to 128: one plan, one miss.
        let b1 = bucket_len(100, 64);
        let b2 = bucket_len(120, 64);
        assert_eq!(b1, b2);
        let e1 = c.get_or_build(key(b1), || build_serving(Variant::Causal, &shape(b1), 1));
        let e2 = c.get_or_build(key(b2), || build_serving(Variant::Causal, &shape(b2), 1));
        assert!(Arc::ptr_eq(&e1, &e2), "same bucket must reuse the plan");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn different_bucket_misses() {
        let mut c = PlanCache::new(8);
        let b1 = bucket_len(100, 64); // 128
        let b2 = bucket_len(200, 64); // 256
        assert_ne!(b1, b2);
        let e1 = c.get_or_build(key(b1), || build_serving(Variant::Causal, &shape(b1), 1));
        let e2 = c.get_or_build(key(b2), || build_serving(Variant::Causal, &shape(b2), 1));
        assert!(!Arc::ptr_eq(&e1, &e2));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 2, 2));
    }

    #[test]
    fn cache_size_is_bounded_with_lru_eviction() {
        let mut c = PlanCache::new(2);
        let buckets = [64, 128, 192];
        for &b in &buckets {
            c.get_or_build(key(b), || build_serving(Variant::Causal, &shape(b), 1));
        }
        assert_eq!(c.len(), 2, "capacity must bound the cache");
        assert_eq!(c.stats().evictions, 1);
        // 64 was least recently used and must have been evicted: touching
        // it again is a miss; 192 is still resident: a hit.
        let before = c.stats().misses;
        c.get_or_build(key(192), || build_serving(Variant::Causal, &shape(192), 1));
        assert_eq!(c.stats().misses, before, "192 must still be cached");
        c.get_or_build(key(64), || build_serving(Variant::Causal, &shape(64), 1));
        assert_eq!(c.stats().misses, before + 1, "64 must have been evicted");
    }

    #[test]
    fn cached_entry_carries_a_fused_plan_and_tile() {
        let mut c = PlanCache::new(4);
        let e = c.get_or_build(key(128), || build_serving(Variant::Causal, &shape(128), 1));
        assert!(e.plan.num_pipelines() >= 1, "{}", e.plan.describe(&e.graph));
        assert!(e.tile.block_q >= 1 && e.tile.block_k >= 1);
    }

    #[test]
    fn cached_plan_carries_static_block_masks_for_index_masks() {
        use crate::variants::build;
        let mut c = PlanCache::new(4);
        let s = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 64,
            head_dim: 16,
        };
        let e = c.get_or_build(key(999), || build(Variant::Causal, &s));
        assert_eq!(e.block_masks.len(), e.plan.groups.len());
        assert!(
            e.block_masks.iter().flatten().any(|m| m.skipped_tiles() > 0),
            "causal prefill must classify some empty k-tiles"
        );
    }

    #[test]
    fn autotune_is_deterministic() {
        let g = build_serving(Variant::Causal, &shape(256), 1);
        let p = plan(&g, FusionMode::Flashlight);
        let t1 = autotune_tile(&g, &p);
        let t2 = autotune_tile(&g, &p);
        assert_eq!(t1.block_q, t2.block_q);
        assert_eq!(t1.block_k, t2.block_k);
    }

    #[test]
    fn hit_rate_math() {
        let s = CacheStats {
            hits: 9,
            misses: 1,
            entries: 1,
            evictions: 0,
        };
        assert!((s.hit_rate() - 0.9).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }
}
