//! The fusion planner: partitions a tensor program into kernel groups by
//! applying the paper's rewrites.
//!
//! Modes:
//! * `Eager` — every node is its own kernel (PyTorch eager semantics).
//! * `TorchCompile` — TorchInductor-style fusion: pointwise chains fuse
//!   with identical sketches, reductions absorb pointwise prologues,
//!   GEMMs absorb simple pointwise epilogues — but GEMMs never fuse with
//!   reductions, and two dependent reductions never fuse (§3.1/§3.4's
//!   "bifurcation" and "synchronization barrier").
//! * `Flashlight` — additionally applies the paper's rewrites:
//!   1. unified-reduction GEMM modeling (§3.1),
//!   2. structural fusion with dimension demotion (§3.2),
//!   3. semantic fusion via the online-softmax algebraic rewrite (§3.4),
//!   4. tiling-aware dimension elimination (§3.5),
//!   discovering the FlashAttention loop structure from idiomatic code.

use std::collections::{HashMap, HashSet};

use crate::exec::{node_flops, Counters};
use crate::fusion::blockmask;
use crate::ir::{Graph, NodeId, Op, PwOp};
use crate::sketch::{analyze, find_softmax_patterns, DimAnalysis, DimClass};

/// Max head-dim extent eligible for tiling-aware elimination (§3.5): a
/// p-dimension collapses only if one tile covers it (`B_P >= |P|`).
pub const MAX_ELIM_DIM: usize = 256;

/// Materialization threshold (§3.7): the max number of ops fused into
/// one non-pipeline kernel before intermediates are forced to
/// materialize. The baseline compiler keeps a low limit; Flashlight
/// raises it so complex fused subgraphs (e.g. ALiBi's score chain)
/// stay in a single kernel without premature materialization.
pub const INDUCTOR_MATERIALIZE_THRESHOLD: usize = 12;
pub const FLASHLIGHT_MATERIALIZE_THRESHOLD: usize = 64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionMode {
    Eager,
    TorchCompile,
    Flashlight,
}

/// Which rewrite fired (for the plan log / `inspect` CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    UnifiedReductionGemm,
    StructuralDemotion,
    AlgebraicOnline,
    TilingElimination,
    PrologueFusion,
    EpilogueFusion,
    PointwiseFusion,
}

#[derive(Debug, Clone)]
pub struct RewriteEvent {
    pub rule: Rule,
    pub at: NodeId,
}

/// Softmax roles inside a fused pipeline.
#[derive(Debug, Clone, Copy)]
pub struct SoftmaxRoles {
    pub max: NodeId,
    pub exp: NodeId,
    pub sum: NodeId,
    pub div: NodeId,
}

/// A fully fused FlashAttention-style kernel: first matmul, score chain,
/// optional online softmax, second matmul, pointwise epilogue.
#[derive(Debug, Clone)]
pub struct Pipeline {
    pub m1: NodeId,
    /// The pre-softmax score node (input of `max`/`exp`), or the lhs of
    /// `m2` when there is no softmax (twin-matmul case).
    pub score_root: NodeId,
    pub softmax: Option<SoftmaxRoles>,
    pub m2: NodeId,
    /// Final node of the group (after epilogue absorption).
    pub out: NodeId,
    pub q_class: DimClass,
    pub kv_class: DimClass,
    /// Block-sparse mask structure recognized at the score root (a
    /// `Where(cond, value, -1e30)`), when the pipeline has an online
    /// softmax to make tile skipping a provable no-op. `None` for
    /// unmasked variants and twin-matmul pipelines.
    pub mask: Option<blockmask::MaskInfo>,
}

#[derive(Debug, Clone)]
pub enum GroupKind {
    Elementwise,
    Reduction,
    Matmul,
    Pipeline(Pipeline),
}

#[derive(Debug, Clone)]
pub struct KernelGroup {
    pub nodes: Vec<NodeId>,
    pub kind: GroupKind,
}

#[derive(Debug)]
pub struct Plan {
    pub mode: FusionMode,
    pub groups: Vec<KernelGroup>,
    /// node -> group index (inputs: usize::MAX).
    pub assignment: Vec<usize>,
    pub log: Vec<RewriteEvent>,
}

/// Tiling schedule used for traffic accounting of pipeline groups.
#[derive(Debug, Clone, Copy)]
pub struct TileConfig {
    pub block_q: usize,
    pub block_k: usize,
    /// L2 capacity: per-operand re-read working sets larger than this
    /// spill to HBM instead of hitting L2.
    pub l2_capacity: u64,
}

impl Default for TileConfig {
    fn default() -> Self {
        TileConfig {
            block_q: 128,
            block_k: 64,
            l2_capacity: 40 << 20,
        }
    }
}

fn is_generator(op: &Op) -> bool {
    matches!(op, Op::Const { .. } | Op::Iota { .. })
}

/// Backward closure from `start` through fusable ops, stopping at
/// `stops`, `Input`s and already-assigned nodes. Returns None if the
/// closure hits a Matmul/Reduce that is not in `stops` (can't absorb).
fn backward_closure(
    g: &Graph,
    start: NodeId,
    stops: &HashSet<NodeId>,
    assigned: &[Option<usize>],
) -> Option<HashSet<NodeId>> {
    let mut seen = HashSet::new();
    let mut stack = vec![start];
    while let Some(id) = stack.pop() {
        if stops.contains(&id) || seen.contains(&id) {
            continue;
        }
        let node = g.node(id);
        match &node.op {
            Op::Input { .. } => continue, // external operand
            Op::Matmul { .. } | Op::Reduce { .. } => return None,
            _ => {}
        }
        if assigned[id.0 as usize].is_some() {
            continue; // produced by an earlier group: external operand
        }
        seen.insert(id);
        stack.extend(node.op.input_ids());
    }
    Some(seen)
}

/// Try to build a flash pipeline rooted at matmul `m1`.
fn try_pipeline(
    g: &Graph,
    an: &DimAnalysis,
    cons: &[Vec<NodeId>],
    softmaxes: &[(NodeId, NodeId, NodeId)],
    m1: NodeId,
    assigned: &[Option<usize>],
    log: &mut Vec<RewriteEvent>,
) -> Option<(HashSet<NodeId>, Pipeline)> {
    let rank = g.node(m1).shape.len();
    let q_class = an.axes[m1.0 as usize][rank - 2];
    let kv_class = an.axes[m1.0 as usize][rank - 1];

    // Look for a softmax pattern whose max-input is downstream of m1 and
    // reduces over m1's N dimension (the demotion candidate).
    for &(mx, ex, sm) in softmaxes {
        let Op::Reduce { input: x, axis, .. } = g.node(mx).op else {
            continue;
        };
        if an.axes[x.0 as usize][axis] != kv_class {
            continue;
        }
        // Score chain: backward closure from x stopping at m1.
        let stops: HashSet<NodeId> = [m1].into_iter().collect();
        let Some(chain) = backward_closure(g, x, &stops, assigned) else {
            continue;
        };
        // m1 must actually feed the chain (or be x itself).
        let feeds = x == m1
            || chain
                .iter()
                .any(|n| g.node(*n).op.input_ids().contains(&m1));
        if !feeds {
            continue;
        }
        // div: pointwise Div consumer of exp dividing by broadcast(sum).
        let mut div = None;
        for &c in &cons[ex.0 as usize] {
            if let Op::Pointwise {
                op: PwOp::Div,
                ref inputs,
            } = g.node(c).op
            {
                if inputs[0] == ex {
                    div = Some(c);
                }
            }
        }
        let div = div?;
        // m2: matmul consumer of div contracting over kv_class.
        let mut m2 = None;
        for &c in &cons[div.0 as usize] {
            if let Op::Matmul { lhs, .. } = g.node(c).op {
                if lhs == div && an.sketches[c.0 as usize].r.contains(&kv_class) {
                    m2 = Some(c);
                }
            }
        }
        let m2 = m2?;
        // Tiling-aware elimination precondition (§3.5): m2's output
        // head-dim must fit one tile so its p-loop collapses.
        let m2_rank = g.node(m2).shape.len();
        let d_out = g.node(m2).shape[m2_rank - 1];
        if d_out > MAX_ELIM_DIM {
            return None;
        }

        // Assemble the group.
        let mut nodes: HashSet<NodeId> = chain;
        nodes.insert(m1);
        nodes.insert(mx);
        nodes.insert(ex);
        nodes.insert(sm);
        nodes.insert(div);
        nodes.insert(m2);
        // broadcasts of max/sum feeding sub/div
        for id in g.ids() {
            if let Op::Broadcast { input } = g.node(id).op {
                if (input == mx || input == sm) && assigned[id.0 as usize].is_none() {
                    nodes.insert(id);
                }
            }
        }
        // sub node (x's producer path is already in chain, but the sub
        // between x and exp sits forward of x): exp's operand.
        if let Op::Pointwise { ref inputs, .. } = g.node(ex).op {
            for &i in inputs {
                if assigned[i.0 as usize].is_none()
                    && !matches!(g.node(i).op, Op::Input { .. })
                {
                    nodes.insert(i);
                    for j in g.node(i).op.input_ids() {
                        if !matches!(g.node(j).op, Op::Input { .. })
                            && assigned[j.0 as usize].is_none()
                            && (g.node(j).op.is_pointwise())
                        {
                            nodes.insert(j);
                        }
                    }
                }
            }
        }
        // Prologues of the matmul operands (slices/pointwise/views).
        for src in [
            g.node(m1).op.input_ids(),
            g.node(m2).op.input_ids(),
        ]
        .concat()
        {
            if nodes.contains(&src) {
                continue;
            }
            let stops: HashSet<NodeId> = nodes.iter().copied().collect();
            if let Some(pro) = backward_closure_prologue(g, src, &stops, assigned) {
                if !pro.is_empty() {
                    log.push(RewriteEvent {
                        rule: Rule::PrologueFusion,
                        at: src,
                    });
                }
                nodes.extend(pro);
            }
        }

        // Legality: every in-group node's consumers stay in-group,
        // except m2 (the group output so far).
        for &n in &nodes {
            if n == m2 {
                continue;
            }
            if cons[n.0 as usize].iter().any(|c| !nodes.contains(c)) {
                return None;
            }
        }

        log.push(RewriteEvent {
            rule: Rule::UnifiedReductionGemm,
            at: m1,
        });
        log.push(RewriteEvent {
            rule: Rule::StructuralDemotion,
            at: mx,
        });
        log.push(RewriteEvent {
            rule: Rule::AlgebraicOnline,
            at: sm,
        });
        log.push(RewriteEvent {
            rule: Rule::TilingElimination,
            at: m2,
        });

        // Epilogue absorption: follow pointwise consumers of m2.
        let mut out = m2;
        loop {
            let next = cons[out.0 as usize]
                .iter()
                .copied()
                .filter(|c| {
                    matches!(g.node(*c).op, Op::Pointwise { .. })
                        && assigned[c.0 as usize].is_none()
                })
                .collect::<Vec<_>>();
            if next.len() != 1 {
                break;
            }
            let c = next[0];
            // Epilogue p-dims must be within the pipeline output's dims.
            let cp: HashSet<DimClass> =
                an.sketches[c.0 as usize].p.iter().copied().collect();
            let op_: HashSet<DimClass> =
                an.sketches[out.0 as usize].p.iter().copied().collect();
            if !cp.is_subset(&op_) || !an.sketches[c.0 as usize].r.is_empty() {
                break;
            }
            // Absorb the side-operand generator trees too.
            let mut ok = true;
            let mut extra = HashSet::new();
            for opnd in g.node(c).op.input_ids() {
                if nodes.contains(&opnd) || matches!(g.node(opnd).op, Op::Input { .. })
                {
                    continue;
                }
                if assigned[opnd.0 as usize].is_some() {
                    continue; // external, already materialized
                }
                let stops: HashSet<NodeId> = nodes.iter().copied().collect();
                match backward_closure_prologue(g, opnd, &stops, assigned) {
                    Some(t) => {
                        extra.insert(opnd);
                        extra.extend(t);
                    }
                    None => {
                        ok = false;
                    }
                }
            }
            if !ok {
                break;
            }
            // side nodes' consumers must be within the new group
            let mut trial = nodes.clone();
            trial.insert(c);
            trial.extend(extra.iter().copied());
            if extra
                .iter()
                .any(|n| cons[n.0 as usize].iter().any(|cc| !trial.contains(cc)))
            {
                break;
            }
            nodes = trial;
            out = c;
            log.push(RewriteEvent {
                rule: Rule::EpilogueFusion,
                at: c,
            });
        }

        return Some((
            nodes,
            Pipeline {
                m1,
                score_root: x,
                softmax: Some(SoftmaxRoles {
                    max: mx,
                    exp: ex,
                    sum: sm,
                    div,
                }),
                m2,
                out,
                q_class,
                kv_class,
                mask: blockmask::extract(g, x),
            },
        ));
    }

    // Twin-matmul (no softmax, §3.5's motivating example): a pointwise
    // chain from m1 into a matmul m2 contracting over m1's N.
    try_twin_matmul(g, an, cons, m1, assigned, log, q_class, kv_class)
}

/// Prologue closure: like `backward_closure` but returns Some(empty) when
/// `start` itself is an Input/assigned node (pure external operand).
fn backward_closure_prologue(
    g: &Graph,
    start: NodeId,
    stops: &HashSet<NodeId>,
    assigned: &[Option<usize>],
) -> Option<HashSet<NodeId>> {
    if matches!(g.node(start).op, Op::Input { .. })
        || assigned[start.0 as usize].is_some()
        || stops.contains(&start)
    {
        return Some(HashSet::new());
    }
    let mut set = backward_closure(g, start, stops, assigned)?;
    set.insert(start);
    Some(set)
}

#[allow(clippy::too_many_arguments)]
fn try_twin_matmul(
    g: &Graph,
    an: &DimAnalysis,
    cons: &[Vec<NodeId>],
    m1: NodeId,
    assigned: &[Option<usize>],
    log: &mut Vec<RewriteEvent>,
    q_class: DimClass,
    kv_class: DimClass,
) -> Option<(HashSet<NodeId>, Pipeline)> {
    // Walk forward through single-consumer pointwise nodes.
    let mut cur = m1;
    let mut chain: HashSet<NodeId> = HashSet::new();
    for _ in 0..16 {
        let cs = &cons[cur.0 as usize];
        if cs.len() != 1 {
            return None;
        }
        let c = cs[0];
        match g.node(c).op {
            Op::Pointwise { .. } => {
                chain.insert(c);
                cur = c;
            }
            Op::Matmul { lhs, .. } => {
                if lhs != cur || !an.sketches[c.0 as usize].r.contains(&kv_class) {
                    return None;
                }
                let m2 = c;
                let m2_rank = g.node(m2).shape.len();
                if g.node(m2).shape[m2_rank - 1] > MAX_ELIM_DIM {
                    return None;
                }
                let mut nodes = chain;
                nodes.insert(m1);
                nodes.insert(m2);
                for &n in &nodes {
                    if n != m2
                        && cons[n.0 as usize].iter().any(|x| !nodes.contains(x))
                    {
                        return None;
                    }
                }
                let _ = assigned;
                log.push(RewriteEvent {
                    rule: Rule::UnifiedReductionGemm,
                    at: m1,
                });
                log.push(RewriteEvent {
                    rule: Rule::StructuralDemotion,
                    at: m2,
                });
                log.push(RewriteEvent {
                    rule: Rule::TilingElimination,
                    at: m2,
                });
                let score_root = cur;
                return Some((
                    nodes,
                    Pipeline {
                        m1,
                        score_root,
                        softmax: None,
                        m2,
                        out: m2,
                        q_class,
                        kv_class,
                        // No softmax: a skipped tile's -1e30·V contribution
                        // would not cancel, so twin-matmul stays dense.
                        mask: None,
                    },
                ));
            }
            _ => return None,
        }
    }
    None
}

/// TorchInductor-style grouping over `pending` nodes (used for the whole
/// graph in `TorchCompile` mode and for pipeline leftovers in
/// `Flashlight` mode).
fn inductor_partition(
    g: &Graph,
    an: &DimAnalysis,
    assigned: &mut [Option<usize>],
    groups: &mut Vec<KernelGroup>,
    log: &mut Vec<RewriteEvent>,
    materialize_threshold: usize,
) {
    struct GState {
        p: Vec<DimClass>,
        has_reduce: bool,
        has_matmul: bool,
    }
    let mut states: HashMap<usize, GState> = HashMap::new();

    for id in g.ids() {
        if assigned[id.0 as usize].is_some() {
            continue;
        }
        let node = g.node(id);
        if matches!(node.op, Op::Input { .. }) {
            continue;
        }
        let my_p: Vec<DimClass> = an.sketches[id.0 as usize].p.clone();
        let my_p_set: HashSet<DimClass> = my_p.iter().copied().collect();
        let is_reduce = matches!(node.op, Op::Reduce { .. });
        let is_matmul = matches!(node.op, Op::Matmul { .. });
        let is_pw = node.op.is_pointwise() || matches!(node.op, Op::Slice { .. });

        // Try to join a producer's group. Joining group `gi` is only
        // legal if no operand comes from a *later* group — groups
        // execute in index order, so that would be a scheduling cycle
        // (e.g. softmax's `sub` may not rejoin the QK^T group: its
        // broadcast(max) operand is produced after it).
        let operand_groups: Vec<Option<usize>> = node
            .op
            .input_ids()
            .iter()
            .map(|o| assigned[o.0 as usize])
            .collect();
        let mut target: Option<usize> = None;
        if !is_matmul {
            for opnd in node.op.input_ids() {
                let Some(gi) = assigned[opnd.0 as usize] else {
                    continue;
                };
                if operand_groups.iter().flatten().any(|&gj| gj > gi) {
                    continue; // would depend on a later group
                }
                let Some(st) = states.get(&gi) else { continue };
                if matches!(groups[gi].kind, GroupKind::Pipeline(_)) {
                    continue;
                }
                let sp: HashSet<DimClass> = st.p.iter().copied().collect();
                let join = if groups[gi].nodes.len() >= materialize_threshold {
                    false // materialization threshold reached (§3.7)
                } else if is_pw {
                    // pointwise epilogue: identical p-dims; GEMM groups
                    // accept only "simple elementwise" epilogues.
                    sp == my_p_set || (st.has_matmul && my_p_set.is_subset(&sp))
                } else if is_reduce {
                    // prologue fusion into a reduction kernel: producer
                    // group must be pure pointwise with matching p-dims.
                    !st.has_reduce && !st.has_matmul
                        && my_p_set.is_subset(&sp)
                } else {
                    false
                };
                if join {
                    target = Some(gi);
                    break;
                }
            }
        }

        match target {
            Some(gi) => {
                groups[gi].nodes.push(id);
                assigned[id.0 as usize] = Some(gi);
                let st = states
                    .get_mut(&gi)
                    .expect("fusion target chosen from `states` keys above");
                if is_reduce {
                    st.has_reduce = true;
                    st.p = my_p;
                    groups[gi].kind = GroupKind::Reduction;
                }
                log.push(RewriteEvent {
                    rule: if is_reduce {
                        Rule::PrologueFusion
                    } else {
                        Rule::PointwiseFusion
                    },
                    at: id,
                });
            }
            None => {
                let kind = if is_matmul {
                    GroupKind::Matmul
                } else if is_reduce {
                    GroupKind::Reduction
                } else {
                    GroupKind::Elementwise
                };
                let gi = groups.len();
                groups.push(KernelGroup {
                    nodes: vec![id],
                    kind,
                });
                states.insert(
                    gi,
                    GState {
                        p: my_p,
                        has_reduce: is_reduce,
                        has_matmul: is_matmul,
                    },
                );
                assigned[id.0 as usize] = Some(gi);
            }
        }
    }
}

/// Partition the graph under the given fusion mode (mode-default
/// materialization threshold).
pub fn plan(g: &Graph, mode: FusionMode) -> Plan {
    let thr = match mode {
        FusionMode::TorchCompile => INDUCTOR_MATERIALIZE_THRESHOLD,
        _ => FLASHLIGHT_MATERIALIZE_THRESHOLD,
    };
    plan_with_threshold(g, mode, thr)
}

/// Partition with an explicit materialization threshold (§3.7 ablation).
pub fn plan_with_threshold(g: &Graph, mode: FusionMode, threshold: usize) -> Plan {
    let an = analyze(g);
    let cons = g.consumers();
    let mut assigned: Vec<Option<usize>> = vec![None; g.nodes.len()];
    let mut groups: Vec<KernelGroup> = vec![];
    let mut log: Vec<RewriteEvent> = vec![];

    match mode {
        FusionMode::Eager => {
            for id in g.ids() {
                if matches!(g.node(id).op, Op::Input { .. }) {
                    continue;
                }
                let kind = match g.node(id).op {
                    Op::Matmul { .. } => GroupKind::Matmul,
                    Op::Reduce { .. } => GroupKind::Reduction,
                    _ => GroupKind::Elementwise,
                };
                assigned[id.0 as usize] = Some(groups.len());
                groups.push(KernelGroup {
                    nodes: vec![id],
                    kind,
                });
            }
        }
        FusionMode::TorchCompile => {
            inductor_partition(g, &an, &mut assigned, &mut groups, &mut log, threshold);
        }
        FusionMode::Flashlight => {
            let softmaxes = find_softmax_patterns(g, &an);
            // Pipelines first (in topo order of m1).
            for id in g.ids() {
                if assigned[id.0 as usize].is_some()
                    || !matches!(g.node(id).op, Op::Matmul { .. })
                {
                    continue;
                }
                if let Some((nodes, pipe)) =
                    try_pipeline(g, &an, &cons, &softmaxes, id, &assigned, &mut log)
                {
                    let gi = groups.len();
                    let mut sorted: Vec<NodeId> = nodes.iter().copied().collect();
                    sorted.sort();
                    for &n in &sorted {
                        assigned[n.0 as usize] = Some(gi);
                    }
                    groups.push(KernelGroup {
                        nodes: sorted,
                        kind: GroupKind::Pipeline(pipe),
                    });
                }
            }
            // Everything else: inductor rules with the raised
            // materialization threshold (§3.7).
            inductor_partition(g, &an, &mut assigned, &mut groups, &mut log, threshold);
        }
    }

    let assignment = assigned
        .iter()
        .map(|a| a.unwrap_or(usize::MAX))
        .collect();
    Plan {
        mode,
        groups,
        assignment,
        log,
    }
}

impl Plan {
    /// Execute this plan on the tiled engine. `par` selects the grid
    /// scheduling (sequential or multi-threaded); outputs and counters
    /// are bit-identical at any thread count.
    pub fn execute(
        &self,
        g: &Graph,
        inputs: &HashMap<String, crate::exec::Tensor>,
        tile: TileConfig,
        par: crate::exec::Parallelism,
    ) -> (Vec<crate::exec::Tensor>, Counters) {
        crate::exec::execute_plan_par(g, self, inputs, tile, &par)
    }

    /// Analytic counters for executing this plan once with the given
    /// tiling schedule (pipeline groups only use the schedule).
    pub fn counters(&self, g: &Graph, tile: TileConfig) -> Counters {
        let an = analyze(g);
        let cons = g.consumers(); // computed once, not per group/node
        let outputs: HashSet<NodeId> = g.outputs.iter().copied().collect();
        let mut c = Counters::default();
        for (gi, grp) in self.groups.iter().enumerate() {
            let members: HashSet<NodeId> = grp.nodes.iter().copied().collect();
            c.launches += 1;
            // flops: dense work of all member nodes
            for &n in &grp.nodes {
                c.flops += node_flops(g, n);
            }
            // reads: unique external operands. In pipeline groups, the
            // tile schedule determines how often each operand is
            // re-touched: K/V-like operands (kv-dim but no q-dim) are
            // re-read once per q-tile; operands broadcast over outer
            // dims (GQA kv heads, Evoformer pair bias over rows) are
            // re-read once per broadcast replica. First touch is HBM
            // (compulsory); re-reads hit L2 unless the operand exceeds
            // its capacity (then they spill back to HBM).
            let mut seen = HashSet::new();
            let pipe = match &grp.kind {
                GroupKind::Pipeline(p) => Some(p),
                _ => None,
            };
            let (n_qtiles, outer) = match pipe {
                Some(p) => {
                    let sq = an.size(p.q_class);
                    let out_axes = &an.axes[p.out.0 as usize];
                    let out_shape = &g.node(p.out).shape;
                    let rank = out_shape.len();
                    let q_ax = out_axes
                        .iter()
                        .position(|cl| *cl == p.q_class)
                        .unwrap_or(rank - 2);
                    // outer classes with sizes (all out axes except q, d)
                    let outer: Vec<(DimClass, usize)> = (0..rank)
                        .filter(|&ax| ax != q_ax && ax != rank - 1)
                        .map(|ax| (out_axes[ax], out_shape[ax]))
                        .collect();
                    (sq.div_ceil(tile.block_q) as u64, outer)
                }
                None => (1, vec![]),
            };
            // Block-sparse traffic: with an input-free index mask on a
            // softmax pipeline, K/V-like operands are charged per *live*
            // k element of the classified (block_q x block_k) grid —
            // skipped tiles are never gathered. Dense pipelines (and
            // masks needing runtime inputs) keep the full-pass formula.
            let bm = match pipe {
                Some(p) if p.softmax.is_some() && blockmask::enabled() => p
                    .mask
                    .as_ref()
                    .filter(|m| m.is_input_free())
                    .and_then(|m| {
                        let s_shape = &g.node(p.score_root).shape;
                        let rank = s_shape.len();
                        blockmask::classify(
                            g,
                            m,
                            s_shape,
                            rank - 2,
                            rank - 1,
                            tile.block_q,
                            tile.block_k,
                            &HashMap::new(),
                        )
                    })
                    .filter(|m| m.dep_axes.is_empty()),
                _ => None,
            };
            for &n in &grp.nodes {
                for opnd in g.node(n).op.input_ids() {
                    if members.contains(&opnd) || !seen.insert(opnd) {
                        continue;
                    }
                    // generators materialize only in eager mode
                    if is_generator(&g.node(opnd).op)
                        && self.mode != FusionMode::Eager
                        && self.assignment[opnd.0 as usize] == usize::MAX
                    {
                        continue;
                    }
                    let bytes = 4 * g.numel(opnd) as u64;
                    let (total, first, working_set) = match pipe {
                        Some(p) => {
                            let axes = &an.axes[opnd.0 as usize];
                            let shape = &g.node(opnd).shape;
                            let covers = |cl: DimClass| {
                                axes.iter()
                                    .zip(shape)
                                    .any(|(c2, &sz)| *c2 == cl && sz > 1)
                            };
                            // broadcast multiplicity over outer dims, and
                            // the per-outer-iteration slice size (the L2
                            // working set the swizzle keeps resident).
                            let mut mult: u64 = 1;
                            let mut covered: u64 = 1;
                            for &(cl, sz) in &outer {
                                if sz > 1 && !covers(cl) {
                                    mult *= sz as u64;
                                } else if sz > 1 {
                                    covered *= sz as u64;
                                }
                            }
                            let has_kv = covers(p.kv_class);
                            let has_q = covers(p.q_class);
                            let (t_total, t_first) = if has_kv && !has_q {
                                match &bm {
                                    Some(m) => {
                                        // Per-k-element slab of this operand:
                                        // visited tiles drive total reads,
                                        // ever-live tiles the compulsory
                                        // first touch.
                                        let per_k = bytes / m.sk as u64;
                                        (
                                            mult * per_k * m.visited_k_elems(),
                                            per_k * m.touched_k_elems() as u64,
                                        )
                                    }
                                    None => (mult * n_qtiles * bytes, bytes),
                                }
                            } else {
                                (mult * bytes, bytes)
                            };
                            (t_total, t_first, bytes / covered.max(1))
                        }
                        None => (bytes, bytes, bytes),
                    };
                    c.hbm_read += first;
                    let reread = total.saturating_sub(first);
                    if working_set <= tile.l2_capacity {
                        c.l2_read += reread;
                    } else {
                        c.hbm_read += reread;
                    }
                }
            }
            // writes: nodes visible outside the group
            for &n in &grp.nodes {
                let external = outputs.contains(&n)
                    || cons[n.0 as usize]
                        .iter()
                        .any(|cc| self.assignment[cc.0 as usize] != gi);
                if external {
                    c.hbm_write += 4 * g.numel(n) as u64;
                }
            }
        }
        // workspace: bytes of all materialized intermediates (non-output)
        let mut live = 0u64;
        for id in g.ids() {
            if matches!(g.node(id).op, Op::Input { .. }) || outputs.contains(&id) {
                continue;
            }
            let gi = self.assignment[id.0 as usize];
            if gi == usize::MAX {
                continue;
            }
            let external = cons[id.0 as usize]
                .iter()
                .any(|cc| self.assignment[cc.0 as usize] != gi);
            if external || self.mode == FusionMode::Eager {
                live += 4 * g.numel(id) as u64;
            }
        }
        c.peak_workspace = live;
        c
    }

    pub fn num_pipelines(&self) -> usize {
        self.groups
            .iter()
            .filter(|gr| matches!(gr.kind, GroupKind::Pipeline(_)))
            .count()
    }

    /// Computation sketch of a kernel group in the paper's §3.2 notation
    /// `[(P0, P1, ...), (R0, R1, ...)]` with extents. For pipelines the
    /// demoted kv dimension is shown on the R side — the visible effect
    /// of the §3.2 rewrite.
    pub fn group_sketch(&self, g: &Graph, an: &DimAnalysis, grp: &KernelGroup) -> String {
        let fmt_dims = |dims: &[DimClass]| {
            dims.iter()
                .map(|c| an.size(*c).to_string())
                .collect::<Vec<_>>()
                .join(", ")
        };
        match &grp.kind {
            GroupKind::Pipeline(p) => {
                let out_sk = &an.sketches[p.out.0 as usize];
                let ps: Vec<DimClass> = out_sk
                    .p
                    .iter()
                    .copied()
                    .filter(|c| *c != p.kv_class)
                    .collect();
                let mut rs = vec![p.kv_class];
                // the first matmul's contraction also stays an inner loop
                rs.extend(an.sketches[p.m1.0 as usize].r.iter().copied());
                format!("[({}), ({})]", fmt_dims(&ps), fmt_dims(&rs))
            }
            _ => {
                // the group's anchor node: last reduction/matmul, else last
                let anchor = grp
                    .nodes
                    .iter()
                    .rev()
                    .find(|n| {
                        matches!(
                            g.node(**n).op,
                            Op::Reduce { .. } | Op::Matmul { .. }
                        )
                    })
                    .or_else(|| grp.nodes.last())
                    .copied()
                    .expect("non-empty group");
                let sk = &an.sketches[anchor.0 as usize];
                format!("[({}), ({})]", fmt_dims(&sk.p), fmt_dims(&sk.r))
            }
        }
    }

    pub fn describe(&self, g: &Graph) -> String {
        use std::fmt::Write;
        let an = analyze(g);
        let mut s = String::new();
        writeln!(s, "plan[{:?}] for `{}`: {} kernels", self.mode, g.name, self.groups.len())
            .unwrap();
        for (i, grp) in self.groups.iter().enumerate() {
            let kind = match &grp.kind {
                GroupKind::Elementwise => "elementwise".to_string(),
                GroupKind::Reduction => "reduction".to_string(),
                GroupKind::Matmul => "matmul".to_string(),
                GroupKind::Pipeline(p) => format!(
                    "flash-pipeline(online_softmax={})",
                    p.softmax.is_some()
                ),
            };
            writeln!(
                s,
                "  kernel {i}: {kind} [{} nodes] sketch {}",
                grp.nodes.len(),
                self.group_sketch(g, &an, grp)
            )
            .unwrap();
        }
        for e in &self.log {
            writeln!(s, "  rewrite {:?} at node {}", e.rule, e.at.0).unwrap();
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::variants::{build, AttnShape, Variant};

    fn shape() -> AttnShape {
        AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 4,
            heads_kv: 2,
            seq: 64,
            head_dim: 16,
        }
    }

    #[test]
    fn flashlight_fuses_vanilla_attention_into_one_kernel() {
        let g = build(Variant::Vanilla, &shape());
        let p = plan(&g, FusionMode::Flashlight);
        assert_eq!(p.num_pipelines(), 1, "{}", p.describe(&g));
        // everything lives in the pipeline: exactly 1 kernel
        assert_eq!(p.groups.len(), 1, "{}", p.describe(&g));
        let rules: Vec<Rule> = p.log.iter().map(|e| e.rule).collect();
        assert!(rules.contains(&Rule::UnifiedReductionGemm));
        assert!(rules.contains(&Rule::StructuralDemotion));
        assert!(rules.contains(&Rule::AlgebraicOnline));
        assert!(rules.contains(&Rule::TilingElimination));
    }

    #[test]
    fn flashlight_fuses_all_paper_variants() {
        for v in crate::variants::paper_variants() {
            let g = build(v, &shape());
            let p = plan(&g, FusionMode::Flashlight);
            assert_eq!(
                p.num_pipelines(),
                1,
                "{}: {}",
                v.name(),
                p.describe(&g)
            );
            assert_eq!(p.groups.len(), 1, "{}", v.name());
        }
    }

    #[test]
    fn diff_attn_fuses_into_two_pipelines() {
        let g = build(Variant::DiffAttn { lambda: 0.5 }, &shape());
        let p = plan(&g, FusionMode::Flashlight);
        assert_eq!(p.num_pipelines(), 2, "{}", p.describe(&g));
        // epilogue (mul_scalar + sub) must be fused: 2 kernels total
        assert_eq!(p.groups.len(), 2, "{}", p.describe(&g));
    }

    #[test]
    fn evoformer_fuses_gating_epilogue() {
        let g = build(Variant::Evoformer, &shape());
        let p = plan(&g, FusionMode::Flashlight);
        assert_eq!(p.num_pipelines(), 1, "{}", p.describe(&g));
        assert_eq!(p.groups.len(), 1, "{}", p.describe(&g));
    }

    #[test]
    fn twin_matmul_fuses_without_softmax() {
        // E = (A @ B) @ D with small inner p-dim (§3.5's example).
        let mut b = GraphBuilder::new("twin");
        let a = b.input("a", &[256, 64]);
        let bb = b.input("b", &[64, 128]);
        let d = b.input("d", &[128, 32]);
        let c = b.matmul(a, bb);
        let e = b.matmul(c, d);
        let g = b.finish(&[e]);
        let p = plan(&g, FusionMode::Flashlight);
        assert_eq!(p.num_pipelines(), 1, "{}", p.describe(&g));
    }

    #[test]
    fn torch_compile_does_not_fuse_across_gemm_or_reductions() {
        let g = build(Variant::Vanilla, &shape());
        let p = plan(&g, FusionMode::TorchCompile);
        assert_eq!(p.num_pipelines(), 0);
        // must be several kernels: QK^T(+scale), max, sub-exp-sum, div, PV
        assert!(p.groups.len() >= 4, "{}", p.describe(&g));
    }

    #[test]
    fn eager_is_one_kernel_per_node() {
        let g = build(Variant::Vanilla, &shape());
        let p = plan(&g, FusionMode::Eager);
        let non_input = g
            .ids()
            .filter(|i| !matches!(g.node(*i).op, Op::Input { .. }))
            .count();
        assert_eq!(p.groups.len(), non_input);
    }

    #[test]
    fn traffic_ordering_flashlight_lt_torchcompile_lt_eager() {
        let g = build(Variant::Causal, &shape());
        let tc = TileConfig::default();
        let fl = plan(&g, FusionMode::Flashlight).counters(&g, tc);
        let ind = plan(&g, FusionMode::TorchCompile).counters(&g, tc);
        let eag = plan(&g, FusionMode::Eager).counters(&g, tc);
        assert!(
            fl.total_traffic() < ind.total_traffic(),
            "flashlight {} vs inductor {}",
            fl.total_traffic(),
            ind.total_traffic()
        );
        assert!(ind.total_traffic() < eag.total_traffic());
        assert!(fl.launches < ind.launches);
        assert!(ind.launches < eag.launches);
        // fused peak workspace excludes the S^2 intermediates
        assert!(fl.peak_workspace < ind.peak_workspace);
    }

    #[test]
    fn eager_group_counters_match_reference_executor() {
        let g = build(Variant::Causal, &shape());
        let p = plan(&g, FusionMode::Eager);
        let c1 = p.counters(&g, TileConfig::default());
        let c2 = crate::exec::eager_counters(&g);
        assert_eq!(c1.hbm_read, c2.hbm_read);
        assert_eq!(c1.hbm_write, c2.hbm_write);
        assert_eq!(c1.flops, c2.flops);
        assert_eq!(c1.launches, c2.launches);
    }

    #[test]
    fn sketch_notation_shows_demotion() {
        // §3.2 made visible: under torch.compile, QK^T's sketch keeps kv
        // as a p-dimension; the flash pipeline demotes it to an r-dim.
        let g = build(Variant::Causal, &shape());
        let fl = plan(&g, FusionMode::Flashlight);
        let d = fl.describe(&g);
        assert!(
            d.contains("sketch [(2, 2, 64, 16), (64, 16)]"),
            "pipeline sketch missing demoted kv dim:\n{d}"
        );
        let tc = plan(&g, FusionMode::TorchCompile);
        let d = tc.describe(&g);
        assert!(
            d.contains("(2, 2, 64, 64), (16)"),
            "matmul sketch should keep kv as p-dim:\n{d}"
        );
    }

    #[test]
    fn large_head_dim_blocks_tiling_elimination() {
        let mut b = GraphBuilder::new("bighead");
        let q = b.input("q", &[1, 1, 1, 64, 16]);
        let k = b.input("k", &[1, 1, 1, 64, 16]);
        // v with head dim 512 > MAX_ELIM_DIM
        let v = b.input("v", &[1, 1, 1, 64, 512]);
        let s = b.matmul_nt(q, k);
        let w = b.softmax(s, 4);
        let o = b.matmul(w, v);
        let g = b.finish(&[o]);
        let p = plan(&g, FusionMode::Flashlight);
        assert_eq!(p.num_pipelines(), 0, "{}", p.describe(&g));
    }
}
