//! GPU cost model: translates measured traffic/flop counters into
//! estimated kernel times on the paper's testbeds (H100 / A100).
//!
//! FlashAttention-class kernels sit between the bandwidth and compute
//! roofs, so per-kernel time is modeled as
//! `launch + max(bytes / BW, flops / (peak * efficiency))` with the SM
//! clock capped the way the paper caps it (H100 1290 MHz, A100 1080 MHz,
//! §4.1). Efficiency factors encode the per-system kernel quality the
//! paper measures and explains (§4.2): FlexAttention's templated kernel
//! carries full/partial/empty-block handling instructions; FlashInfer's
//! hand-tuned CUDA is the fastest dense pipeline; Flashlight's generated
//! kernel is template-free. The *traffic and flop inputs* come from the
//! compiler's plans and executors, not from hand formulas.

use crate::exec::Counters;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// HBM bandwidth, bytes/s (effective, at the capped clock).
    pub hbm_bw: f64,
    /// L2 cache bandwidth, bytes/s — serves intra-kernel re-reads
    /// (counted separately in [`Counters::l2_read`]).
    pub l2_bw: f64,
    /// L2 capacity in bytes; re-read working sets beyond this spill to
    /// HBM (handled in the planner's analytic accounting).
    pub l2_capacity: u64,
    /// Peak bf16 tensor-core flops/s at the capped SM clock.
    pub peak_flops: f64,
    /// Kernel launch + scheduling overhead, seconds.
    pub launch_s: f64,
    /// Host-side cost of building / inspecting a block mask
    /// (FlexAttention's `create_block_mask`: several small kernels, a
    /// dense mask_mod evaluation, and a D2H sync — §3.8/§4.2).
    pub mask_host_s: f64,
}

/// NVIDIA H100 80GB SXM, SM clock capped to 1290 MHz (paper §4.1):
/// HBM3 3.35 TB/s; bf16 tensor peak 989 TFLOP/s at 1980 MHz boost
/// scales to ~644 TFLOP/s at the cap.
pub fn h100() -> GpuSpec {
    GpuSpec {
        name: "H100",
        hbm_bw: 3.35e12,
        l2_bw: 12.0e12,
        l2_capacity: 50 << 20,
        peak_flops: 989e12 * (1290.0 / 1980.0),
        launch_s: 4.0e-6,
        mask_host_s: 300e-6,
    }
}

/// NVIDIA A100 80GB, SM clock capped to 1080 MHz (paper §4.1): HBM2e
/// 2.0 TB/s; bf16 tensor peak 312 TFLOP/s at 1410 MHz -> ~239 TFLOP/s.
pub fn a100() -> GpuSpec {
    GpuSpec {
        name: "A100",
        hbm_bw: 2.0e12,
        l2_bw: 7.0e12,
        l2_capacity: 40 << 20,
        peak_flops: 312e12 * (1080.0 / 1410.0),
        launch_s: 4.0e-6,
        mask_host_s: 360e-6,
    }
}

pub fn gpu_by_name(name: &str) -> GpuSpec {
    match name.to_ascii_lowercase().as_str() {
        "h100" => h100(),
        "a100" => a100(),
        other => panic!("unknown GPU {other} (expected h100|a100)"),
    }
}

/// Achieved-fraction-of-peak for the compute roof of each kernel family.
#[derive(Debug, Clone, Copy)]
pub struct Efficiency {
    /// MXU/tensor-core utilization on the matmul portions.
    pub compute: f64,
    /// Achieved fraction of HBM bandwidth.
    pub memory: f64,
}

impl Efficiency {
    pub const fn new(compute: f64, memory: f64) -> Self {
        Efficiency { compute, memory }
    }
}

/// Kernel-time estimate from measured counters: the slowest of the HBM
/// roof, the L2 roof and the compute roof, plus launch overhead.
pub fn kernel_time(spec: &GpuSpec, c: &Counters, eff: Efficiency) -> f64 {
    let hbm = c.total_traffic() as f64 / (spec.hbm_bw * eff.memory);
    let l2 = c.l2_read as f64 / spec.l2_bw;
    let cmp = c.flops as f64 / (spec.peak_flops * eff.compute);
    spec.launch_s * c.launches as f64 + hbm.max(l2).max(cmp)
}

/// Roofline characterization of a kernel (for EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    pub arithmetic_intensity: f64,
    pub memory_bound: bool,
    pub attained_fraction_of_peak: f64,
}

pub fn roofline(spec: &GpuSpec, c: &Counters, eff: Efficiency) -> Roofline {
    let ai = c.flops as f64 / c.total_traffic().max(1) as f64;
    let ridge = spec.peak_flops / spec.hbm_bw;
    let t = kernel_time(spec, c, eff);
    Roofline {
        arithmetic_intensity: ai,
        memory_bound: ai < ridge,
        attained_fraction_of_peak: c.flops as f64 / t / spec.peak_flops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(read: u64, write: u64, flops: u64, launches: u64) -> Counters {
        Counters {
            hbm_read: read,
            l2_read: 0,
            hbm_write: write,
            flops,
            launches,
            ..Counters::default()
        }
    }

    #[test]
    fn l2_reads_are_cheaper_than_hbm_reads() {
        let spec = h100();
        let eff = Efficiency::new(0.5, 0.8);
        let hbm_heavy = c(1 << 33, 0, 1000, 1);
        let mut l2_heavy = c(1 << 20, 0, 1000, 1);
        l2_heavy.l2_read = 1 << 33;
        assert!(
            kernel_time(&spec, &l2_heavy, eff) < kernel_time(&spec, &hbm_heavy, eff)
        );
    }

    #[test]
    fn memory_bound_kernel_scales_with_traffic() {
        let spec = h100();
        let eff = Efficiency::new(0.5, 0.8);
        let t1 = kernel_time(&spec, &c(1 << 30, 0, 1000, 1), eff);
        let t2 = kernel_time(&spec, &c(1 << 31, 0, 1000, 1), eff);
        assert!(t2 / t1 > 1.9 && t2 / t1 < 2.1);
    }

    #[test]
    fn compute_bound_kernel_ignores_small_traffic_changes() {
        let spec = h100();
        let eff = Efficiency::new(0.5, 0.8);
        let big_flops = 1u64 << 45;
        let t1 = kernel_time(&spec, &c(1024, 1024, big_flops, 1), eff);
        let t2 = kernel_time(&spec, &c(2048, 2048, big_flops, 1), eff);
        assert_eq!(t1, t2);
    }

    #[test]
    fn launch_overhead_dominates_tiny_kernels() {
        let spec = a100();
        let eff = Efficiency::new(1.0, 1.0);
        let t = kernel_time(&spec, &c(64, 64, 64, 10), eff);
        assert!(t > 9.0 * spec.launch_s);
    }

    #[test]
    fn h100_is_faster_than_a100() {
        let eff = Efficiency::new(0.5, 0.8);
        let work = c(1 << 32, 1 << 30, 1 << 40, 4);
        assert!(kernel_time(&h100(), &work, eff) < kernel_time(&a100(), &work, eff));
    }

    #[test]
    fn roofline_classifies_attention_as_expected() {
        let spec = h100();
        // arithmetic intensity below ridge -> memory bound
        let low = c(1 << 30, 1 << 30, 1 << 32, 1);
        assert!(roofline(&spec, &low, Efficiency::new(0.5, 0.8)).memory_bound);
        let high = c(1 << 20, 1 << 20, 1 << 45, 1);
        assert!(!roofline(&spec, &high, Efficiency::new(0.5, 0.8)).memory_bound);
    }
}
