//! Graph container and a builder with PyTorch-flavoured helpers.

use super::{broadcast_shapes, numel, CmpOp, Op, PwOp, ReduceOp, Shape};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

#[derive(Debug, Clone)]
pub struct Node {
    pub op: Op,
    pub shape: Shape,
}

/// A tensor program: SSA nodes in topological order (construction order),
/// with designated inputs and outputs.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub inputs: Vec<NodeId>,
    pub outputs: Vec<NodeId>,
    pub name: String,
}

impl Graph {
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    pub fn ids(&self) -> impl Iterator<Item = NodeId> {
        (0..self.nodes.len() as u32).map(NodeId)
    }

    pub fn numel(&self, id: NodeId) -> usize {
        numel(&self.node(id).shape)
    }

    /// Consumers of each node (computed on demand).
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut cons = vec![Vec::new(); self.nodes.len()];
        for id in self.ids() {
            for src in self.node(id).op.input_ids() {
                cons[src.0 as usize].push(id);
            }
        }
        cons
    }

    /// Total elements materialized by eager execution (all non-input nodes).
    pub fn total_intermediate_elems(&self) -> usize {
        self.ids()
            .filter(|id| !matches!(self.node(*id).op, Op::Input { .. }))
            .map(|id| self.numel(id))
            .sum()
    }
}

/// Builder exposing an idiomatic tensor API — the analog of writing the
/// attention variant in native PyTorch (paper Listings 1/3/4). Everything
/// the builder emits is plain IR; no attention-specific node exists.
pub struct GraphBuilder {
    g: Graph,
}

impl GraphBuilder {
    pub fn new(name: &str) -> Self {
        GraphBuilder {
            g: Graph {
                name: name.to_string(),
                ..Default::default()
            },
        }
    }

    fn push(&mut self, op: Op, shape: Shape) -> NodeId {
        let id = NodeId(self.g.nodes.len() as u32);
        self.g.nodes.push(Node { op, shape });
        id
    }

    pub fn shape(&self, id: NodeId) -> &Shape {
        &self.g.node(id).shape
    }

    pub fn input(&mut self, name: &str, shape: &[usize]) -> NodeId {
        let id = self.push(
            Op::Input {
                name: name.to_string(),
            },
            shape.to_vec(),
        );
        self.g.inputs.push(id);
        id
    }

    pub fn constant(&mut self, value: f32, shape: &[usize]) -> NodeId {
        self.push(Op::Const { value }, shape.to_vec())
    }

    pub fn iota(&mut self, shape: &[usize], axis: usize) -> NodeId {
        assert!(axis < shape.len());
        self.push(Op::Iota { axis }, shape.to_vec())
    }

    /// Broadcast `x` (with size-1 dims) to `shape`.
    pub fn broadcast(&mut self, x: NodeId, shape: &[usize]) -> NodeId {
        let xs = self.shape(x).clone();
        assert_eq!(xs.len(), shape.len(), "broadcast rank mismatch");
        for (a, b) in xs.iter().zip(shape) {
            assert!(*a == *b || *a == 1, "broadcast {xs:?} -> {shape:?}");
        }
        if xs == shape {
            return x;
        }
        self.push(Op::Broadcast { input: x }, shape.to_vec())
    }

    fn pointwise(&mut self, op: PwOp, inputs: Vec<NodeId>) -> NodeId {
        assert_eq!(op.arity(), inputs.len(), "{op:?} arity");
        let mut shape = self.shape(inputs[0]).clone();
        for x in &inputs[1..] {
            shape = broadcast_shapes(&shape, self.shape(*x))
                .unwrap_or_else(|| panic!("pointwise shape mismatch {op:?}"));
        }
        // Insert explicit broadcasts so executors never broadcast implicitly.
        let inputs = inputs
            .into_iter()
            .map(|x| self.broadcast(x, &shape.clone()))
            .collect();
        self.push(Op::Pointwise { op, inputs }, shape)
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.pointwise(PwOp::Add, vec![a, b])
    }
    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.pointwise(PwOp::Sub, vec![a, b])
    }
    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.pointwise(PwOp::Mul, vec![a, b])
    }
    pub fn div(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.pointwise(PwOp::Div, vec![a, b])
    }
    pub fn exp(&mut self, a: NodeId) -> NodeId {
        self.pointwise(PwOp::Exp, vec![a])
    }
    pub fn tanh(&mut self, a: NodeId) -> NodeId {
        self.pointwise(PwOp::Tanh, vec![a])
    }
    pub fn sigmoid(&mut self, a: NodeId) -> NodeId {
        self.pointwise(PwOp::Sigmoid, vec![a])
    }
    pub fn neg(&mut self, a: NodeId) -> NodeId {
        self.pointwise(PwOp::Neg, vec![a])
    }
    pub fn maximum(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.pointwise(PwOp::Maximum, vec![a, b])
    }
    pub fn mul_scalar(&mut self, a: NodeId, s: f32) -> NodeId {
        self.pointwise(PwOp::MulScalar(s), vec![a])
    }
    pub fn add_scalar(&mut self, a: NodeId, s: f32) -> NodeId {
        self.pointwise(PwOp::AddScalar(s), vec![a])
    }
    pub fn cmp(&mut self, op: CmpOp, a: NodeId, b: NodeId) -> NodeId {
        self.pointwise(PwOp::Cmp(op), vec![a, b])
    }
    /// `select(cond, a, b)` — cond is 0/1-valued.
    pub fn where_(&mut self, cond: NodeId, a: NodeId, b: NodeId) -> NodeId {
        self.pointwise(PwOp::Where, vec![cond, a, b])
    }
    /// Mask positions where `keep == 0` to a large negative value
    /// (`masked_fill(~keep, -INF)` in the paper's Listing 1).
    pub fn masked_fill_neg(&mut self, x: NodeId, keep: NodeId) -> NodeId {
        let neg = self.constant(crate::exec::NEG_INF, &self.shape(x).clone());
        self.where_(keep, x, neg)
    }

    /// `a @ b` over the last two dims; batch dims of `b` may be 1.
    pub fn matmul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.matmul_impl(a, b, false)
    }

    /// `a @ b.transpose(-2, -1)` — the natural `Q Kᵀ` form.
    pub fn matmul_nt(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.matmul_impl(a, b, true)
    }

    fn matmul_impl(&mut self, a: NodeId, b: NodeId, transpose_rhs: bool) -> NodeId {
        let sa = self.shape(a).clone();
        let sb = self.shape(b).clone();
        assert_eq!(sa.len(), sb.len(), "matmul rank mismatch {sa:?} {sb:?}");
        let r = sa.len();
        assert!(r >= 2);
        let (m, ka) = (sa[r - 2], sa[r - 1]);
        let (kb, n) = if transpose_rhs {
            (sb[r - 1], sb[r - 2])
        } else {
            (sb[r - 2], sb[r - 1])
        };
        assert_eq!(ka, kb, "matmul contraction mismatch {sa:?} {sb:?}");
        let mut shape = Vec::with_capacity(r);
        for i in 0..r - 2 {
            assert!(sb[i] == sa[i] || sb[i] == 1, "matmul batch {sa:?} {sb:?}");
            shape.push(sa[i]);
        }
        shape.push(m);
        shape.push(n);
        self.push(
            Op::Matmul {
                lhs: a,
                rhs: b,
                transpose_rhs,
            },
            shape,
        )
    }

    /// Reduce with keepdim (size-1 on `axis`).
    pub fn reduce(&mut self, op: ReduceOp, x: NodeId, axis: usize) -> NodeId {
        let mut shape = self.shape(x).clone();
        assert!(axis < shape.len());
        shape[axis] = 1;
        self.push(Op::Reduce { op, input: x, axis }, shape)
    }

    pub fn max_reduce(&mut self, x: NodeId, axis: usize) -> NodeId {
        self.reduce(ReduceOp::Max, x, axis)
    }
    pub fn sum_reduce(&mut self, x: NodeId, axis: usize) -> NodeId {
        self.reduce(ReduceOp::Sum, x, axis)
    }

    pub fn slice(&mut self, x: NodeId, axis: usize, start: usize, len: usize) -> NodeId {
        let mut shape = self.shape(x).clone();
        assert!(start + len <= shape[axis], "slice out of range");
        shape[axis] = len;
        self.push(
            Op::Slice {
                input: x,
                axis,
                start,
                len,
            },
            shape,
        )
    }

    /// Numerically-stable softmax over `axis` — written exactly the way
    /// idiomatic framework code writes it (two passes; paper Alg. 1).
    /// The *compiler* is responsible for discovering the online form.
    pub fn softmax(&mut self, x: NodeId, axis: usize) -> NodeId {
        let shape = self.shape(x).clone();
        let m = self.max_reduce(x, axis);
        let mb = self.broadcast(m, &shape);
        let shifted = self.sub(x, mb);
        let p = self.exp(shifted);
        let l = self.sum_reduce(p, axis);
        let lb = self.broadcast(l, &shape);
        self.div(p, lb)
    }

    pub fn output(&mut self, id: NodeId) {
        self.g.outputs.push(id);
    }

    pub fn finish(mut self, outputs: &[NodeId]) -> Graph {
        for &o in outputs {
            self.g.outputs.push(o);
        }
        self.g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_builds_two_pass_pattern() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8]);
        let s = b.softmax(x, 1);
        let g = b.finish(&[s]);
        let n_max = g
            .ids()
            .filter(|i| matches!(g.node(*i).op, Op::Reduce { op: ReduceOp::Max, .. }))
            .count();
        let n_sum = g
            .ids()
            .filter(|i| matches!(g.node(*i).op, Op::Reduce { op: ReduceOp::Sum, .. }))
            .count();
        assert_eq!((n_max, n_sum), (1, 1));
        assert_eq!(g.node(s).shape, vec![4, 8]);
    }

    #[test]
    fn matmul_shapes() {
        let mut b = GraphBuilder::new("t");
        let q = b.input("q", &[2, 3, 16, 8]);
        let k = b.input("k", &[2, 3, 32, 8]);
        let s = b.matmul_nt(q, k);
        assert_eq!(b.shape(s), &vec![2, 3, 16, 32]);
        let v = b.input("v", &[2, 3, 32, 8]);
        let o = b.matmul(s, v);
        assert_eq!(b.shape(o), &vec![2, 3, 16, 8]);
    }

    #[test]
    #[should_panic(expected = "matmul contraction mismatch")]
    fn matmul_rejects_bad_contraction() {
        let mut b = GraphBuilder::new("t");
        let a = b.input("a", &[4, 8]);
        let c = b.input("c", &[4, 8]);
        b.matmul(a, c);
    }

    #[test]
    fn broadcast_identity_is_noop() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8]);
        assert_eq!(b.broadcast(x, &[4, 8]), x);
    }
}
