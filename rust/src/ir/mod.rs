//! Tensor-program IR with the paper's unified reduction model (§3.1).
//!
//! Every operation is classified by which of its dimensions are
//! *p-dimensions* (data-independent, present in the output) and which are
//! *r-dimensions* (reduced / data-dependent). Crucially, `Matmul` is an
//! ordinary node in the same IR — a sum-reduction over its contracted
//! dimension — instead of an opaque library call. This is what dismantles
//! the GEMM fusion boundary that TorchInductor's special-path creates.
//!
//! Graphs are built by the frontends in [`crate::variants`] from idiomatic
//! attention code (the analog of the paper's Listings 1/3/4) and consumed
//! by the sketch extractor, the fusion planner, and both executors.

mod graph;
mod ops;

pub use graph::{Graph, GraphBuilder, Node, NodeId};
pub use ops::{CmpOp, Op, PwOp, ReduceOp};

/// Static tensor shape. All tensors are f32 on the simulated device
/// (paper §3.7: GEMM accumulation is unconditionally promoted to fp32;
/// lower-precision I/O is modeled by the cost layer's `bytes_per_elem`).
pub type Shape = Vec<usize>;

/// Number of elements of a shape.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Broadcast-compatibility of two equal-rank shapes (size-1 stretches).
pub fn broadcast_shapes(a: &[usize], b: &[usize]) -> Option<Shape> {
    if a.len() != b.len() {
        return None;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| match (x, y) {
            (x, y) if x == y => Some(x),
            (1, y) => Some(y),
            (x, 1) => Some(x),
            _ => None,
        })
        .collect()
}
