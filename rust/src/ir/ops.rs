//! Operation vocabulary for the unified reduction IR.

/// Element-wise (pointwise) operators. All operands share one broadcasted
/// shape; these are always p-dimension-only ops (sketch `[(P...), ()]`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PwOp {
    Add,
    Sub,
    Mul,
    Div,
    Neg,
    Exp,
    Exp2,
    Tanh,
    Sigmoid,
    Recip,
    Sqrt,
    Rsqrt,
    Abs,
    Maximum,
    Minimum,
    /// `select(cond, a, b)`: cond is a 0/1-valued tensor.
    Where,
    /// Binary comparison producing 0/1.
    Cmp(CmpOp),
    /// Fused multiply-add `a * b + c` (ternary).
    MulAdd,
    /// Multiply by a compile-time scalar (kept immediate: no memory operand).
    MulScalar(f32),
    /// Add a compile-time scalar.
    AddScalar(f32),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Le,
    Lt,
    Ge,
    Gt,
    Eq,
    Ne,
    And,
    Or,
}

impl PwOp {
    pub fn arity(&self) -> usize {
        match self {
            PwOp::Neg
            | PwOp::Exp
            | PwOp::Exp2
            | PwOp::Tanh
            | PwOp::Sigmoid
            | PwOp::Recip
            | PwOp::Sqrt
            | PwOp::Rsqrt
            | PwOp::Abs
            | PwOp::MulScalar(_)
            | PwOp::AddScalar(_) => 1,
            PwOp::Where | PwOp::MulAdd => 3,
            _ => 2,
        }
    }
}

/// Reduction operators. `Sum` and `Max` are the two monoids the paper's
/// algebraic machinery needs: softmax's two passes are a Max-reduction
/// followed by a Sum-reduction whose body applies the homomorphism `exp`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
}

impl ReduceOp {
    /// Identity element of the reduction monoid.
    pub fn identity(&self) -> f32 {
        match self {
            ReduceOp::Sum => 0.0,
            ReduceOp::Max => f32::NEG_INFINITY,
        }
    }

    pub fn combine(&self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => a.max(b),
        }
    }
}

/// IR nodes. Shapes are stored on the graph node, not the op.
#[derive(Debug, Clone)]
pub enum Op {
    /// External input (HBM-resident operand).
    Input { name: String },
    /// Scalar constant, logically broadcast to the node shape. The fused
    /// executor materializes nothing; the eager/reference executor counts a
    /// full write+read, matching eager PyTorch's materialized constants.
    Const { value: f32 },
    /// Index values along `axis`, broadcast over the other dims. Eager
    /// PyTorch materializes these (`torch.arange(...).view(...)`, paper
    /// Listing 3); a fused kernel regenerates them in registers.
    Iota { axis: usize },
    /// Element-wise op over broadcast-compatible operands.
    Pointwise { op: PwOp, inputs: Vec<crate::ir::NodeId> },
    /// Batched matrix product `[..., M, K] x [..., K, N] -> [..., M, N]`.
    /// With `transpose_rhs`, rhs is `[..., N, K]` (computes `A Bᵀ`, the
    /// natural QKᵀ form). Batch dims of rhs may be 1 (broadcast).
    Matmul {
        lhs: crate::ir::NodeId,
        rhs: crate::ir::NodeId,
        transpose_rhs: bool,
    },
    /// Reduce `axis` with keepdim semantics (output size 1 on `axis`).
    Reduce {
        op: ReduceOp,
        input: crate::ir::NodeId,
        axis: usize,
    },
    /// Stretch size-1 dims of `input` to the node shape (explicit
    /// broadcast; the materializing executor pays for it like eager does).
    Broadcast { input: crate::ir::NodeId },
    /// Static slice along `axis`: elements `[start, start + len)`.
    /// Used by e.g. differential attention's `chunk` (paper Listing 4).
    Slice {
        input: crate::ir::NodeId,
        axis: usize,
        start: usize,
        len: usize,
    },
}

impl Op {
    pub fn input_ids(&self) -> Vec<crate::ir::NodeId> {
        match self {
            Op::Input { .. } | Op::Const { .. } | Op::Iota { .. } => vec![],
            Op::Pointwise { inputs, .. } => inputs.clone(),
            Op::Matmul { lhs, rhs, .. } => vec![*lhs, *rhs],
            Op::Reduce { input, .. }
            | Op::Broadcast { input }
            | Op::Slice { input, .. } => vec![*input],
        }
    }

    pub fn is_pointwise(&self) -> bool {
        matches!(
            self,
            Op::Pointwise { .. } | Op::Const { .. } | Op::Iota { .. } | Op::Broadcast { .. }
        )
    }
}
