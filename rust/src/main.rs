//! flashlight CLI: compile/inspect attention programs, run the fused
//! executor, regenerate the paper's figures, and serve the tiny model.

use flashlight::bench;
use flashlight::cost::gpu_by_name;
use flashlight::exec::{execute_plan_par, Parallelism, Tensor};
use flashlight::fusion::{plan, FusionMode, TileConfig};
use flashlight::variants::{build, AttnShape, Variant};

fn usage() -> ! {
    eprintln!(
        "usage: flashlight <command> [args]\n\
         commands:\n\
         \x20 inspect <variant> [--mode eager|torchcompile|flashlight]\n\
         \x20     print the fusion plan for an attention variant\n\
         \x20 run <variant> [--seq N] [--batch N] [--threads N]\n\
         \x20     execute fused vs reference and compare numerics/traffic\n\
         \x20     (--threads > 1 also cross-checks the parallel engine)\n\
         \x20 bench <fig2..fig7|alphafold|masks|ablations|engine|serve_engine|all>\n\
         \x20       [--gpu h100|a100] [--threads N]\n\
         \x20     regenerate a paper figure's series (CSV to bench_results/);\n\
         \x20     `engine` measures seq-vs-parallel executor wall clock\n\
         \x20     plus the GEMM/softmax microkernel table\n\
         \x20     (default threads: FLASHLIGHT_THREADS env — integer >= 1,\n\
         \x20     invalid values warn and fall back to all cores;\n\
         \x20     FLASHLIGHT_SIMD=0 forces the scalar kernel tier, =avx2\n\
         \x20     caps an AVX-512 host at the AVX2 tier;\n\
         \x20     FLASHLIGHT_TOPO=flat|DxW|c0,c1,.. overrides the worker\n\
         \x20     runtime's cache/NUMA scheduling topology;\n\
         \x20     FLASHLIGHT_BLOCKMASK=0 disables block-sparse tile\n\
         \x20     skipping — dense fallback, every k-tile visited);\n\
         \x20     `serve_engine` measures engine-backend serve throughput\n\
         \x20     at 1/2/all threads with the bit-identity gate\n\
         \x20 serve [--requests N] [--backend sim|engine|pjrt] [--threads N]\n\
         \x20       [--layers L] [--chunk N] [--prefill-budget N]\n\
         \x20       [--deadline-ms MS] [--queue-cap N] [--live] [--shards N]\n\
         \x20     run the serving coordinator on a Mooncake-like trace;\n\
         \x20     `engine` executes requests on the real tiled engine\n\
         \x20     (slot-paged KV, pre-warmed plan cache, chunked prefill\n\
         \x20     batched with decode, L-layer model, prefix reuse)\n\
         \x20     under the fault-tolerant lifecycle (bounded ingress,\n\
         \x20     deadlines/cancels, KV-pressure preemption; inject\n\
         \x20     faults via FLASHLIGHT_FAULTS, see serve/README.md);\n\
         \x20     --chunk 0 disables chunking; --prefill-budget bounds\n\
         \x20     per-round prefill work in row-layer units (one prompt\n\
         \x20     row through one layer, so tokens x L per full row);\n\
         \x20     --deadline-ms applies a default completion SLO,\n\
         \x20     --queue-cap bounds the ingress queue (0 = unbounded),\n\
         \x20     --kv-pages caps the KV page pool (0 = uncapped);\n\
         \x20     --live serves the trace through a real ingress thread\n\
         \x20     with per-request token streaming under a watchdog\n\
         \x20     supervisor (FLASHLIGHT_STALL_MS, FLASHLIGHT_STREAM_BUF);\n\
         \x20     --shards N serves over N engine instances behind the\n\
         \x20     conversation-sticky router (topology-pinned fault\n\
         \x20     domains, work-stealing admission, shard failover)\n\
         \x20 chaos [--requests N] [--threads N] [--layers L] [--chunk N]\n\
         \x20       [--prefill-budget N] [--kv-pages N] [--plans SPEC[,SPEC..]]\n\
         \x20       [--live] [--shards N]\n\
         \x20     replay the engine trace under deterministic fault\n\
         \x20     plans (pressure windows, worker panics, cancels,\n\
         \x20     deadline storms, stalled launches) and fail loudly\n\
         \x20     unless every request reaches exactly one terminal\n\
         \x20     state, no KV pages leak, and survivors' tokens match\n\
         \x20     the fault-free run; --live re-runs the gates with token\n\
         \x20     streams attached (open-loop arrivals, backoff requeues,\n\
         \x20     watchdog kills) plus a threaded wall-clock drain smoke;\n\
         \x20     --shards N runs the sharded gates instead: sharding\n\
         \x20     1/2/4-way x 1/2/4 threads must be bit-identical, and\n\
         \x20     kill@R:shard=S plans must fail over with exact terminal\n\
         \x20     accounting and no leaks on surviving shards\n\
         \x20 lint\n\
         \x20     statically verify every built-in variant x bucket shape\n\
         \x20     (shape inference, race-freedom, float determinism,\n\
         \x20     mask-skip soundness); exit 1 on any diagnostic\n\
         \x20 selftest\n\
         \x20     load + execute every AOT artifact and cross-check"
    );
    std::process::exit(2)
}

fn parse_variant(name: &str) -> Variant {
    match name {
        "vanilla" => Variant::Vanilla,
        "causal" => Variant::Causal,
        "sliding_window" => Variant::SlidingWindow { window: 256 },
        "alibi" => Variant::Alibi,
        "softcap" => Variant::Softcap { cap: 20.0 },
        "prefix_lm" => Variant::PrefixLm { prefix: 256 },
        "document" => Variant::DocumentMask,
        "diff_attn" => Variant::DiffAttn { lambda: 0.5 },
        "evoformer" => Variant::Evoformer,
        "rectified" => Variant::Rectified { tau: 0.05 },
        other => {
            eprintln!("unknown variant {other}");
            std::process::exit(2)
        }
    }
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    match cmd.as_str() {
        "lint" => {
            let r = flashlight::analysis::lint_builtin_variants();
            print!("{}", r.report);
            if r.failed > 0 {
                eprintln!("flashlight lint: {} plan(s) failed verification", r.failed);
                std::process::exit(1);
            }
        }
        "inspect" => {
            let v = parse_variant(args.get(1).map(String::as_str).unwrap_or("vanilla"));
            let mode = match flag(&args, "--mode").as_deref() {
                Some("eager") => FusionMode::Eager,
                Some("torchcompile") => FusionMode::TorchCompile,
                _ => FusionMode::Flashlight,
            };
            let shape = AttnShape {
                batch: 1,
                rows: 1,
                heads_q: 4,
                heads_kv: 2,
                seq: 512,
                head_dim: 64,
            };
            let g = build(v, &shape);
            let p = plan(&g, mode);
            print!("{}", p.describe(&g));
            let c = p.counters(&g, TileConfig::default());
            println!(
                "traffic: read {} MiB, write {} MiB, {} launches, {:.1} GFLOP",
                c.hbm_read >> 20,
                c.hbm_write >> 20,
                c.launches,
                c.flops as f64 / 1e9
            );
        }
        "run" => {
            let v = parse_variant(args.get(1).map(String::as_str).unwrap_or("vanilla"));
            let seq: usize = flag(&args, "--seq").map(|s| s.parse().unwrap()).unwrap_or(128);
            let batch: usize =
                flag(&args, "--batch").map(|s| s.parse().unwrap()).unwrap_or(1);
            let threads: usize = flag(&args, "--threads")
                .map(|s| s.parse().unwrap())
                .unwrap_or(1);
            let shape = AttnShape {
                batch,
                rows: 1,
                heads_q: 4,
                heads_kv: 2,
                seq,
                head_dim: 32,
            };
            let g = build(v, &shape);
            let mut inputs = std::collections::HashMap::new();
            for (i, &id) in g.inputs.iter().enumerate() {
                let node = g.node(id);
                let flashlight::ir::Op::Input { name } = &node.op else {
                    unreachable!()
                };
                let t = if name.starts_with("doc") {
                    let n: usize = node.shape.iter().product();
                    Tensor::from_vec(
                        &node.shape,
                        (0..n).map(|j| (j * 4 / n) as f32).collect(),
                    )
                } else {
                    Tensor::synthetic(&node.shape, 42 + i as u64)
                };
                inputs.insert(name.clone(), t);
            }
            let (want, c_eager) = flashlight::exec::eval(&g, &inputs);
            let p = plan(&g, FusionMode::Flashlight);
            let par = Parallelism::with_threads(threads);
            let (got, c_fused) = execute_plan_par(&g, &p, &inputs, TileConfig::default(), &par);
            println!(
                "{}: fused kernels={} threads={} max|Δ|={:.2e}",
                v.name(),
                p.groups.len(),
                par.num_threads,
                got[0].max_abs_diff(&want[0])
            );
            if par.is_parallel() {
                // Cross-check: parallel must be bit-identical to sequential.
                let (got_seq, c_seq) =
                    execute_plan_par(&g, &p, &inputs, TileConfig::default(), &Parallelism::sequential());
                let identical = got == got_seq && c_fused == c_seq;
                println!("parallel/sequential bit-identical: {identical}");
                anyhow::ensure!(identical, "parallel engine diverged from sequential");
            }
            println!(
                "traffic: eager {} KiB -> fused {} KiB ({:.1}x less)",
                c_eager.total_traffic() >> 10,
                c_fused.total_traffic() >> 10,
                c_eager.total_traffic() as f64 / c_fused.total_traffic() as f64
            );
        }
        "bench" => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            let gpu = gpu_by_name(&flag(&args, "--gpu").unwrap_or("h100".into()));
            let threads: usize = flag(&args, "--threads")
                .map(|s| s.parse().unwrap())
                .unwrap_or(0); // 0 = all cores
            bench::run(which, &gpu, threads)?;
        }
        "serve" => {
            let n: usize = flag(&args, "--requests")
                .map(|s| s.parse().unwrap())
                .unwrap_or(200);
            let backend = flag(&args, "--backend").unwrap_or("sim".into());
            let threads: usize = flag(&args, "--threads")
                .map(|s| s.parse().unwrap())
                .unwrap_or(1);
            let defaults = flashlight::serve::EngineServeOpts::default();
            let opts = flashlight::serve::EngineServeOpts {
                layers: flag(&args, "--layers")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.layers),
                chunk_tokens: flag(&args, "--chunk")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.chunk_tokens),
                round_tokens: flag(&args, "--prefill-budget")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.round_tokens),
                deadline_ms: flag(&args, "--deadline-ms")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.deadline_ms),
                queue_cap: flag(&args, "--queue-cap")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.queue_cap),
                kv_page_cap: flag(&args, "--kv-pages")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.kv_page_cap),
                live: args.iter().any(|a| a == "--live"),
                shards: flag(&args, "--shards")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.shards),
            };
            flashlight::serve::cli_serve(n, &backend, Parallelism::with_threads(threads), opts)?;
        }
        "chaos" => {
            let n: usize = flag(&args, "--requests")
                .map(|s| s.parse().unwrap())
                .unwrap_or(24);
            let threads: usize = flag(&args, "--threads")
                .map(|s| s.parse().unwrap())
                .unwrap_or(2);
            let defaults = flashlight::serve::EngineServeOpts::default();
            let opts = flashlight::serve::EngineServeOpts {
                layers: flag(&args, "--layers")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.layers),
                chunk_tokens: flag(&args, "--chunk")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.chunk_tokens),
                round_tokens: flag(&args, "--prefill-budget")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.round_tokens),
                kv_page_cap: flag(&args, "--kv-pages")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.kv_page_cap),
                live: args.iter().any(|a| a == "--live"),
                shards: flag(&args, "--shards")
                    .map(|s| s.parse().unwrap())
                    .unwrap_or(defaults.shards),
                ..defaults
            };
            // Plans are comma-separated; events inside one plan are
            // semicolon-separated (the FLASHLIGHT_FAULTS spec syntax).
            let plans: Vec<String> = flag(&args, "--plans")
                .unwrap_or("seed=1,seed=2,pressure@2:6x8;panic@3;storm@6:2".into())
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            flashlight::serve::chaos(n, Parallelism::with_threads(threads), opts, &plans)?;
        }
        "selftest" => {
            flashlight::runtime::selftest("artifacts")?;
        }
        _ => usage(),
    }
    Ok(())
}
