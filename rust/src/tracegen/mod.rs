//! Synthetic Mooncake-like conversation trace (DESIGN.md §2 substitution
//! for https://github.com/kvcache-ai/Mooncake FAST25 traces).
//!
//! The paper replays the first 200 requests of the Mooncake conversation
//! trace through vLLM (§4.4). The statistics that drive the serving
//! metrics are: multi-turn conversations (long shared prefixes), heavily
//! skewed input lengths, shorter outputs, and bursty Poisson-ish
//! arrivals. The generator reproduces those, seeded and deterministic.

/// xorshift64* — deterministic, dependency-free RNG (also used by the
/// property-test helpers).
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next_u64() as usize) % (hi - lo).max(1)
    }

    /// Exponential with mean `mean`.
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Log-normal via Box-Muller.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        let (u1, u2) = (self.f64().max(1e-12), self.f64());
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        (mu + sigma * z).exp()
    }
}

/// One serving request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    /// Arrival time (seconds since trace start).
    pub arrival_s: f64,
    /// Prompt length in tokens (including the conversation history).
    pub input_tokens: usize,
    /// Tokens to generate.
    pub output_tokens: usize,
    /// Conversation this request belongs to (multi-turn reuse).
    pub conversation: usize,
    /// Turn index within the conversation.
    pub turn: usize,
    /// Scheduling priority: higher survives longer under KV pressure
    /// (the lifecycle scheduler preempts the lowest-priority in-flight
    /// request first).
    pub priority: u8,
    /// Completion SLO *budget* relative to arrival, in scheduler-clock
    /// units (seconds under a wall clock, rounds under the deterministic
    /// round clock). `INFINITY` = no deadline.
    pub deadline_s: f64,
    /// Time after arrival at which the client abandons the request
    /// (cancellation). `INFINITY` = never.
    pub cancel_s: f64,
}

impl Default for Request {
    fn default() -> Self {
        Request {
            id: 0,
            arrival_s: 0.0,
            input_tokens: 1,
            output_tokens: 1,
            conversation: 0,
            turn: 0,
            priority: 1,
            deadline_s: f64::INFINITY,
            cancel_s: f64::INFINITY,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub seed: u64,
    pub n_requests: usize,
    /// Mean arrival rate (requests/s).
    pub rate: f64,
    /// Log-normal parameters of the *first-turn* prompt length.
    pub input_mu: f64,
    pub input_sigma: f64,
    /// Mean output length (geometric-ish).
    pub mean_output: f64,
    /// Probability a request continues an existing conversation.
    pub continuation_p: f64,
    /// Hard caps so requests fit the serving model's context window.
    pub max_input: usize,
    pub max_output: usize,
    /// Distinct priority levels (1 = every request gets priority 1; `k`
    /// draws uniformly from `0..k`).
    pub priority_levels: u8,
    /// Fraction of requests carrying a completion deadline.
    pub deadline_p: f64,
    /// Mean deadline budget (relative to arrival) for deadline-bearing
    /// requests; the drawn budget is uniform in `[0.5, 1.5] * slack`.
    pub deadline_slack_s: f64,
    /// Fraction of requests the client abandons mid-flight.
    pub cancel_p: f64,
    /// Mean time-to-cancel for abandoned requests.
    pub cancel_after_s: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 0xF1A5,
            n_requests: 200, // the paper replays the first 200 requests
            rate: 8.0,
            input_mu: 5.0, // e^5 ~ 148 tokens median first turn
            input_sigma: 0.8,
            mean_output: 48.0,
            continuation_p: 0.55,
            max_input: 480,
            max_output: 64,
            priority_levels: 1,
            deadline_p: 0.0,
            deadline_slack_s: 30.0,
            cancel_p: 0.0,
            cancel_after_s: 10.0,
        }
    }
}

/// Generate the trace. Deterministic for a given config.
pub fn generate(cfg: &TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    // Lifecycle fields draw from a *separate* derived stream so turning
    // the knobs on cannot shift the arrivals/lengths stream: the same
    // seed always yields the same base trace, with or without
    // deadlines/cancels/priorities layered on top.
    let mut lrng = Rng::new(cfg.seed ^ 0x9E3779B97F4A7C15);
    let mut t = 0.0f64;
    let mut conversations: Vec<(usize, usize)> = vec![]; // (total_len, turns)
    let mut out = Vec::with_capacity(cfg.n_requests);
    for id in 0..cfg.n_requests {
        t += rng.exp(1.0 / cfg.rate);
        let cont = !conversations.is_empty() && rng.f64() < cfg.continuation_p;
        let (conversation, turn, input_tokens) = if cont {
            let ci = rng.range(0, conversations.len());
            let (hist, turns) = conversations[ci];
            // next turn: history + new user message
            let add = rng.lognormal(cfg.input_mu - 1.0, cfg.input_sigma) as usize + 1;
            let len = (hist + add).min(cfg.max_input);
            conversations[ci] = (len, turns + 1);
            (ci, turns + 1, len)
        } else {
            let len = (rng.lognormal(cfg.input_mu, cfg.input_sigma) as usize + 1)
                .min(cfg.max_input);
            conversations.push((len, 0));
            (conversations.len() - 1, 0, len)
        };
        let output_tokens = ((rng.exp(cfg.mean_output) as usize) + 1).min(cfg.max_output);
        let priority = if cfg.priority_levels > 1 {
            lrng.range(0, cfg.priority_levels as usize) as u8
        } else {
            1
        };
        let deadline_s = if cfg.deadline_p > 0.0 && lrng.f64() < cfg.deadline_p {
            cfg.deadline_slack_s * (0.5 + lrng.f64())
        } else {
            f64::INFINITY
        };
        let cancel_s = if cfg.cancel_p > 0.0 && lrng.f64() < cfg.cancel_p {
            lrng.exp(cfg.cancel_after_s)
        } else {
            f64::INFINITY
        };
        out.push(Request {
            id,
            arrival_s: t,
            input_tokens,
            output_tokens,
            conversation,
            turn,
            priority,
            deadline_s,
            cancel_s,
        });
    }
    out
}

/// How a trace's arrival times are re-timed for open-loop replay (the
/// load axis of goodput-vs-offered-load curves: same requests, same
/// lengths, different interarrival process).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalModel {
    /// Fresh Poisson-process arrivals at `rate` requests per second
    /// (seeded, independent of the trace's own arrival stream).
    Poisson { rate: f64 },
    /// Keep the trace's own interarrival structure, compressed or
    /// stretched by `scale` (0.5 = twice the offered load).
    Replay { scale: f64 },
}

/// Re-time `trace` under `model`, deterministically from `seed`,
/// leaving every non-arrival field byte-identical. The request order
/// (and hence ids, conversations, prompts, outcomes under
/// `ClockMode::Rounds`) is untouched — only `arrival_s` changes, so
/// sweeping offered load never perturbs the workload itself.
pub fn retime_arrivals(trace: &[Request], model: ArrivalModel, seed: u64) -> Vec<Request> {
    let mut out = trace.to_vec();
    match model {
        ArrivalModel::Replay { scale } => {
            for r in &mut out {
                r.arrival_s *= scale;
            }
        }
        ArrivalModel::Poisson { rate } => {
            let mut rng = Rng::new(seed ^ 0xA5A5_1234_5678_9ABC);
            let mut t = 0.0f64;
            for r in &mut out {
                t += rng.exp(1.0 / rate.max(1e-9));
                r.arrival_s = t;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.input_tokens, y.input_tokens);
        }
    }

    #[test]
    fn arrivals_are_monotone_and_rate_plausible() {
        let cfg = TraceConfig::default();
        let t = generate(&cfg);
        for w in t.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        let span = t.last().unwrap().arrival_s;
        let rate = t.len() as f64 / span;
        assert!(rate > cfg.rate * 0.6 && rate < cfg.rate * 1.6, "rate {rate}");
    }

    #[test]
    fn lengths_respect_caps_and_skew() {
        let cfg = TraceConfig::default();
        let t = generate(&cfg);
        assert!(t.iter().all(|r| r.input_tokens <= cfg.max_input));
        assert!(t.iter().all(|r| r.output_tokens <= cfg.max_output));
        assert!(t.iter().all(|r| r.input_tokens >= 1));
        // multi-turn requests exist and have longer inputs on average
        let (mut turn0, mut turnn) = (vec![], vec![]);
        for r in &t {
            if r.turn == 0 {
                turn0.push(r.input_tokens as f64);
            } else {
                turnn.push(r.input_tokens as f64);
            }
        }
        assert!(!turnn.is_empty(), "no multi-turn requests generated");
        let m0 = turn0.iter().sum::<f64>() / turn0.len() as f64;
        let mn = turnn.iter().sum::<f64>() / turnn.len() as f64;
        assert!(mn > m0, "continuations should carry history ({mn} vs {m0})");
    }

    #[test]
    fn lifecycle_knobs_do_not_perturb_the_base_trace() {
        // Adding deadlines/cancels/priorities must not shift the RNG
        // stream that produces arrivals and lengths: downstream serving
        // benches key their baselines off the default trace.
        let base = generate(&TraceConfig::default());
        let spiced = generate(&TraceConfig {
            priority_levels: 4,
            deadline_p: 0.5,
            cancel_p: 0.25,
            ..TraceConfig::default()
        });
        assert!(base
            .iter()
            .all(|r| r.priority == 1 && r.deadline_s.is_infinite() && r.cancel_s.is_infinite()));
        for (b, s) in base.iter().zip(&spiced) {
            assert_eq!(b.arrival_s, s.arrival_s);
            assert_eq!(b.input_tokens, s.input_tokens);
            assert_eq!(b.output_tokens, s.output_tokens);
        }
        assert!(spiced.iter().any(|r| r.deadline_s.is_finite()));
        assert!(spiced.iter().any(|r| r.cancel_s.is_finite()));
        assert!(spiced.iter().any(|r| r.priority != spiced[0].priority));
    }

    #[test]
    fn retiming_changes_only_arrivals_and_is_deterministic() {
        let base = generate(&TraceConfig::default());
        let strip = |t: &[Request]| {
            t.iter()
                .map(|r| (r.id, r.input_tokens, r.output_tokens, r.conversation, r.turn))
                .collect::<Vec<_>>()
        };
        let replay = retime_arrivals(&base, ArrivalModel::Replay { scale: 0.25 }, 0);
        assert_eq!(strip(&base), strip(&replay));
        for (b, r) in base.iter().zip(&replay) {
            assert_eq!(r.arrival_s, b.arrival_s * 0.25);
        }
        let poisson = retime_arrivals(&base, ArrivalModel::Poisson { rate: 32.0 }, 9);
        assert_eq!(strip(&base), strip(&poisson));
        assert!(poisson.windows(2).all(|w| w[1].arrival_s >= w[0].arrival_s));
        let span = poisson.last().unwrap().arrival_s;
        let rate = poisson.len() as f64 / span;
        assert!(rate > 32.0 * 0.6 && rate < 32.0 * 1.6, "rate {rate}");
        let again = retime_arrivals(&base, ArrivalModel::Poisson { rate: 32.0 }, 9);
        for (p, q) in poisson.iter().zip(&again) {
            assert_eq!(p.arrival_s, q.arrival_s);
        }
        // A different seed produces a different arrival stream.
        let other = retime_arrivals(&base, ArrivalModel::Poisson { rate: 32.0 }, 10);
        assert!(poisson.iter().zip(&other).any(|(p, q)| p.arrival_s != q.arrival_s));
    }

    #[test]
    fn rng_uniformity_smoke() {
        let mut rng = Rng::new(7);
        let n = 10_000;
        let mean = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
