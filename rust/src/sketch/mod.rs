//! Computation sketches (paper §3.2) and the dimension analysis behind
//! them.
//!
//! A kernel's sketch `[(P0, P1, ...), (R0, R1, ...)]` captures its loop
//! hierarchy: data-independent p-dimensions form the outer parallel loops,
//! data-dependent r-dimensions the inner iterative loops. To compare
//! sketches *across* nodes (the whole point of fusion rules), dimensions
//! need identity, not just extent: the `M` axis of `QKᵀ` is the same loop
//! as the `M` axis of the downstream softmax. We recover that identity
//! with a union-find over `(node, axis)` pairs, unified through pointwise
//! ops, broadcasts, reductions and matmuls.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::ir::{Graph, NodeId, Op, ReduceOp};

/// Process-wide count of [`analyze`] invocations. Serving gates on this:
/// cached plans carry their analysis, so steady-state decode must not
/// re-analyze — `bench serve_engine` asserts the count stays flat across
/// post-warmup serving rounds.
static ANALYZE_CALLS: AtomicU64 = AtomicU64::new(0);

/// How many times [`analyze`] has run in this process.
pub fn analyze_call_count() -> u64 {
    ANALYZE_CALLS.load(Ordering::Relaxed)
}

/// A canonical dimension class (equivalence class of `(node, axis)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DimClass(pub u32);

/// Sketch of one node (or one fused kernel group).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Sketch {
    /// Parallel dimensions, outermost first.
    pub p: Vec<DimClass>,
    /// Reduction dimensions.
    pub r: Vec<DimClass>,
}

impl Sketch {
    pub fn pointwise(p: Vec<DimClass>) -> Self {
        Sketch { p, r: vec![] }
    }
}

/// Result of dimension analysis over a graph.
pub struct DimAnalysis {
    /// For each node, the dim class of each axis.
    pub axes: Vec<Vec<DimClass>>,
    /// Extent of each dim class.
    pub sizes: HashMap<DimClass, usize>,
    /// Per-node sketch (p-dims in axis order, r-dims for reductions/matmul).
    pub sketches: Vec<Sketch>,
}

struct UnionFind {
    parent: Vec<u32>,
}

impl UnionFind {
    fn new() -> Self {
        UnionFind { parent: vec![] }
    }
    fn fresh(&mut self) -> u32 {
        let id = self.parent.len() as u32;
        self.parent.push(id);
        id
    }
    fn find(&mut self, x: u32) -> u32 {
        let p = self.parent[x as usize];
        if p == x {
            return x;
        }
        let root = self.find(p);
        self.parent[x as usize] = root;
        root
    }
    fn union(&mut self, a: u32, b: u32) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[rb as usize] = ra;
        }
    }
}

/// Run the union-find dimension analysis.
///
/// Unification rules:
/// * pointwise: every operand axis ≡ output axis (size-1 broadcast axes of
///   operands excepted — they get their own degenerate class);
/// * broadcast: non-stretched axes ≡ input axes;
/// * reduce (keepdim): non-reduced axes ≡ input axes; the reduced input
///   axis becomes the node's r-dimension; the size-1 output axis is fresh;
/// * matmul: batch axes ≡ lhs/rhs batch axes (unless broadcast), `M` ≡
///   lhs `M`, `N` ≡ rhs `N`, and the contracted `K` axes of lhs and rhs
///   are unified with each other — that shared class is the r-dimension.
/// * slice: the sliced axis gets a fresh class (different extent); the
///   other axes keep the input's identity.
pub fn analyze(g: &Graph) -> DimAnalysis {
    ANALYZE_CALLS.fetch_add(1, Ordering::Relaxed);
    let mut uf = UnionFind::new();
    // Assign provisional classes: one fresh id per (node, axis).
    let mut raw: Vec<Vec<u32>> = g
        .nodes
        .iter()
        .map(|n| n.shape.iter().map(|_| uf.fresh()).collect())
        .collect();
    // Extra classes for reduction dims that don't appear in outputs
    // (matmul K): map node -> r classes.
    let mut r_of: Vec<Vec<u32>> = vec![vec![]; g.nodes.len()];

    for id in g.ids() {
        let i = id.0 as usize;
        let node = g.node(id);
        match &node.op {
            Op::Input { .. } | Op::Const { .. } | Op::Iota { .. } => {}
            Op::Pointwise { inputs, .. } => {
                for &src in inputs {
                    let s = src.0 as usize;
                    for ax in 0..node.shape.len() {
                        // Builder inserts explicit broadcasts, so operand
                        // shapes match exactly here.
                        uf.union(raw[i][ax], raw[s][ax]);
                    }
                }
            }
            Op::Broadcast { input } => {
                let s = input.0 as usize;
                for ax in 0..node.shape.len() {
                    if g.node(*input).shape[ax] == node.shape[ax] {
                        uf.union(raw[i][ax], raw[s][ax]);
                    }
                    // stretched axes keep their fresh class; pointwise
                    // consumers will unify them with peer operands.
                }
            }
            Op::Reduce { input, axis, .. } => {
                let s = input.0 as usize;
                for ax in 0..node.shape.len() {
                    if ax != *axis {
                        uf.union(raw[i][ax], raw[s][ax]);
                    }
                }
                r_of[i].push(raw[s][*axis]);
            }
            Op::Matmul {
                lhs,
                rhs,
                transpose_rhs,
            } => {
                let (l, r) = (lhs.0 as usize, rhs.0 as usize);
                let rank = node.shape.len();
                for ax in 0..rank - 2 {
                    if g.node(*lhs).shape[ax] == node.shape[ax] {
                        uf.union(raw[i][ax], raw[l][ax]);
                    }
                    if g.node(*rhs).shape[ax] == node.shape[ax] {
                        uf.union(raw[i][ax], raw[r][ax]);
                    }
                }
                // M from lhs, N from rhs.
                uf.union(raw[i][rank - 2], raw[l][rank - 2]);
                let (rhs_k_ax, rhs_n_ax) = if *transpose_rhs {
                    (rank - 1, rank - 2)
                } else {
                    (rank - 2, rank - 1)
                };
                uf.union(raw[i][rank - 1], raw[r][rhs_n_ax]);
                // Contraction: lhs K ≡ rhs K -> the r-dimension.
                uf.union(raw[l][rank - 1], raw[r][rhs_k_ax]);
                r_of[i].push(raw[l][rank - 1]);
            }
            Op::Slice { input, axis, .. } => {
                // Non-sliced axes keep their identity; only the sliced
                // axis changes extent/alignment and gets a fresh class.
                let s = input.0 as usize;
                for ax in 0..node.shape.len() {
                    if ax != *axis {
                        uf.union(raw[i][ax], raw[s][ax]);
                    }
                }
            }
        }
    }

    // Canonicalize.
    let mut sizes = HashMap::new();
    let mut axes = Vec::with_capacity(g.nodes.len());
    for id in g.ids() {
        let i = id.0 as usize;
        let classes: Vec<DimClass> = raw[i]
            .iter()
            .map(|&c| DimClass(uf.find(c)))
            .collect();
        for (ax, &c) in classes.iter().enumerate() {
            let sz = g.node(id).shape[ax];
            let e = sizes.entry(c).or_insert(sz);
            // A class may mix a broadcast size-1 axis with the real
            // extent; keep the max (true extent).
            if sz > *e {
                *e = sz;
            }
        }
        axes.push(classes);
    }
    for r in raw.iter_mut().flatten() {
        *r = uf.find(*r);
    }

    let mut sketches = Vec::with_capacity(g.nodes.len());
    for id in g.ids() {
        let i = id.0 as usize;
        let p: Vec<DimClass> = axes[i]
            .iter()
            .copied()
            .filter(|c| sizes[c] > 1)
            .collect();
        let r: Vec<DimClass> = r_of[i].iter().map(|&c| DimClass(uf.find(c))).collect();
        for &c in &r {
            sizes.entry(c).or_insert(0);
        }
        sketches.push(Sketch { p, r });
    }

    DimAnalysis {
        axes,
        sizes,
        sketches,
    }
}

impl DimAnalysis {
    pub fn sketch(&self, id: NodeId) -> &Sketch {
        &self.sketches[id.0 as usize]
    }

    pub fn size(&self, c: DimClass) -> usize {
        self.sizes[&c]
    }

    /// Is `needle`'s reduced dim among `hay`'s p-dims? (the demotion
    /// precondition of §3.2: consumer r-dim == producer p-dim).
    pub fn reduces_over_p_of(&self, consumer: NodeId, producer: NodeId) -> bool {
        let cr = &self.sketch(consumer).r;
        let pp = &self.sketch(producer).p;
        cr.iter().any(|c| pp.contains(c))
    }
}

/// Detect the two-pass stable-softmax pattern (paper §3.4):
/// `max`-reduce over class `c`, then `exp(x ⊖ broadcast(m))`, then
/// `sum`-reduce over the same class, where `x` is the max's input.
/// Returns (max_node, exp_node, sum_node) triples.
pub fn find_softmax_patterns(g: &Graph, an: &DimAnalysis) -> Vec<(NodeId, NodeId, NodeId)> {
    let cons = g.consumers();
    let mut out = vec![];
    for id in g.ids() {
        let Op::Reduce {
            op: ReduceOp::Max,
            input: x,
            axis,
        } = g.node(id).op
        else {
            continue;
        };
        let r_class = an.axes[x.0 as usize][axis];
        // Follow broadcast -> sub -> exp -> sum chains.
        for &b in &cons[id.0 as usize] {
            let after_b = if matches!(g.node(b).op, Op::Broadcast { .. }) {
                cons[b.0 as usize].clone()
            } else {
                vec![b]
            };
            for &s in &after_b {
                let Op::Pointwise {
                    op: crate::ir::PwOp::Sub,
                    ref inputs,
                } = g.node(s).op
                else {
                    continue;
                };
                if inputs[0] != x {
                    continue;
                }
                for &e in &cons[s.0 as usize] {
                    if !matches!(
                        g.node(e).op,
                        Op::Pointwise {
                            op: crate::ir::PwOp::Exp,
                            ..
                        }
                    ) {
                        continue;
                    }
                    for &sm in &cons[e.0 as usize] {
                        if let Op::Reduce {
                            op: ReduceOp::Sum,
                            input,
                            axis: sum_axis,
                        } = g.node(sm).op
                        {
                            if input == e && an.axes[e.0 as usize][sum_axis] == r_class {
                                out.push((id, e, sm));
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    fn attention_graph() -> Graph {
        let mut b = GraphBuilder::new("attn");
        let q = b.input("q", &[2, 64, 16]);
        let k = b.input("k", &[2, 64, 16]);
        let v = b.input("v", &[2, 64, 16]);
        let s = b.matmul_nt(q, k);
        let s = b.mul_scalar(s, 0.25);
        let w = b.softmax(s, 2);
        let o = b.matmul(w, v);
        b.finish(&[o])
    }

    #[test]
    fn matmul_sketch_has_contraction_r_dim() {
        let g = attention_graph();
        let an = analyze(&g);
        // Node 3 is QK^T: p = [B, M, N], r = [K(=16)].
        let sk = an.sketch(crate::ir::NodeId(3));
        assert_eq!(sk.p.len(), 3);
        assert_eq!(sk.r.len(), 1);
        assert_eq!(an.size(sk.r[0]), 16);
    }

    #[test]
    fn qk_and_softmax_share_dims() {
        let g = attention_graph();
        let an = analyze(&g);
        // The softmax reduction class must equal QK^T's N p-dim class.
        let pats = find_softmax_patterns(&g, &an);
        assert_eq!(pats.len(), 1);
        let (m, _e, s) = pats[0];
        let Op::Reduce { input, axis, .. } = g.node(m).op else {
            panic!()
        };
        let max_r = an.axes[input.0 as usize][axis];
        let Op::Reduce {
            input: si, axis: sa, ..
        } = g.node(s).op
        else {
            panic!()
        };
        assert_eq!(an.axes[si.0 as usize][sa], max_r);
        assert_eq!(an.size(max_r), 64);
    }

    #[test]
    fn demotion_precondition_holds_for_pv_after_qk() {
        let g = attention_graph();
        let an = analyze(&g);
        // PV matmul (last node) reduces over N, which is a p-dim of QK^T.
        let pv = *g.outputs.first().unwrap();
        assert!(an.reduces_over_p_of(pv, crate::ir::NodeId(3)));
    }

    #[test]
    fn broadcast_axes_reunify_through_pointwise() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 32]);
        let m = b.max_reduce(x, 1);
        let mb = b.broadcast(m, &[4, 32]);
        let d = b.sub(x, mb);
        let g = b.finish(&[d]);
        let an = analyze(&g);
        // sub's axis-1 class == x's axis-1 class == mb's stretched axis.
        assert_eq!(an.axes[d.0 as usize][1], an.axes[x.0 as usize][1]);
        assert_eq!(an.axes[mb.0 as usize][1], an.axes[x.0 as usize][1]);
    }
}
