"""AOT pipeline tests: HLO text round-trips and manifest consistency."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels.flash_attention import flash_attention

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip():
    """Lowered HLO text must be parseable and mention the entry module."""
    fn = lambda x, y: (jnp.matmul(x, y) + 1.0,)
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "dot" in text
    assert "ROOT" in text


def test_pallas_kernel_lowers_to_hlo_text():
    """interpret=True pallas must lower to plain HLO (no custom-calls that
    the CPU PJRT client cannot execute)."""
    fn = lambda q, k, v: (flash_attention(q, k, v, variant="causal",
                                          block_q=16, block_k=16),)
    spec = jax.ShapeDtypeStruct((1, 1, 32, 16), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec, spec))
    assert text.startswith("HloModule")
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_all_artifact_files_exist(self, manifest):
        for name, entry in manifest["artifacts"].items():
            path = os.path.join(ART, entry["file"])
            assert os.path.exists(path), name
            with open(path) as f:
                assert f.read(9) == "HloModule", name

    def test_expected_artifacts_present(self, manifest):
        names = set(manifest["artifacts"])
        for v in ("vanilla", "causal", "sliding_window", "alibi", "softcap",
                  "prefix_lm", "document", "bias", "rectified"):
            assert f"attn_{v}_fused" in names
            assert f"attn_{v}_naive" in names
        assert "llama_decode_b8" in names
        assert "evoformer_block_fused" in names

    def test_weight_blob_matches_manifest(self, manifest):
        for family in ("llama", "evoformer"):
            entry = manifest["weights"][family]
            blob = np.fromfile(os.path.join(ART, entry["file"]), np.float32)
            total = sum(
                int(np.prod(t["shape"])) for t in entry["tensors"]
            )
            assert blob.size == total, family

    def test_fused_naive_pairs_have_same_io(self, manifest):
        arts = manifest["artifacts"]
        for name, entry in arts.items():
            if name.endswith("_fused"):
                twin = name[: -len("_fused")] + "_naive"
                assert twin in arts, name
                assert entry["inputs"] == arts[twin]["inputs"]
                assert entry["outputs"] == arts[twin]["outputs"]

    def test_llama_weights_reproducible(self, manifest):
        """init_llama is seeded: the exported blob must match regeneration."""
        from compile import model as M

        params = M.init_llama(aot.LLAMA_CFG)
        leaves = jax.tree_util.tree_leaves(params)
        blob = np.fromfile(
            os.path.join(ART, manifest["weights"]["llama"]["file"]), np.float32
        )
        regen = np.concatenate([np.asarray(l).ravel() for l in leaves])
        np.testing.assert_array_equal(blob, regen)
