"""L1 correctness: Pallas flash kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the fused kernel: every variant,
swept over shapes/dtypes with hypothesis, must match the materializing
two-pass reference to fp32 tolerance. (The online-softmax rewrite is exact
in real arithmetic — paper §3.3/App. A — so only fp rounding differs.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_attention import (
    VARIANTS,
    alibi_slope,
    diff_attention,
    flash_attention,
)

jax.config.update("jax_platform_name", "cpu")


def make_qkv(key, b, hq, hkv, s, d, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    return q, k, v


def variant_kwargs(variant, key, b, s):
    kw = {}
    if variant == "sliding_window":
        kw["window"] = max(1, s // 4)
    if variant == "softcap":
        kw["softcap"] = 15.0
    if variant == "prefix_lm":
        kw["prefix_len"] = max(1, s // 3)
    if variant == "rectified":
        # tau away from 0 so fp reduction-order differences between the
        # tiled kernel and the einsum oracle cannot flip the mask.
        kw["tau"] = 0.05
    if variant == "document":
        kw["doc_ids"] = jnp.sort(
            jax.random.randint(key, (b, s), 0, 3), axis=-1
        )
    if variant == "bias":
        kw["bias"] = 0.2 * jax.random.normal(key, (b, 1, s, s), jnp.float32)
    return kw


@pytest.mark.parametrize("variant", VARIANTS)
def test_variant_matches_ref(variant):
    key = jax.random.PRNGKey(hash(variant) % 2**31)
    q, k, v = make_qkv(key, 2, 4, 4, 128, 64)
    kw = variant_kwargs(variant, jax.random.fold_in(key, 1), 2, 128)
    out = flash_attention(q, k, v, variant=variant, block_q=32, block_k=32, **kw)
    exp = ref.attention_ref(q, k, v, variant=variant, **kw)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("variant", ["vanilla", "causal", "sliding_window"])
@pytest.mark.parametrize("group", [2, 4])
def test_gqa_matches_ref(variant, group):
    key = jax.random.PRNGKey(7)
    hq = 8
    q, k, v = make_qkv(key, 1, hq, hq // group, 64, 32)
    kw = variant_kwargs(variant, key, 1, 64)
    out = flash_attention(q, k, v, variant=variant, block_q=32, block_k=32, **kw)
    exp = ref.attention_ref(q, k, v, variant=variant, **kw)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    variant=st.sampled_from(VARIANTS),
    s_blocks=st.integers(1, 4),
    block=st.sampled_from([16, 32]),
    d=st.sampled_from([16, 32, 64]),
    hq=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**30),
)
def test_hypothesis_shape_sweep(variant, s_blocks, block, d, hq, group, seed):
    """Property: fused kernel == two-pass reference for any legal shape."""
    if hq % group:
        group = 1
    s = s_blocks * block
    key = jax.random.PRNGKey(seed)
    q, k, v = make_qkv(key, 1, hq, hq // group, s, d)
    kw = variant_kwargs(variant, jax.random.fold_in(key, 1), 1, s)
    out = flash_attention(
        q, k, v, variant=variant, block_q=block, block_k=block, **kw
    )
    exp = ref.attention_ref(q, k, v, variant=variant, **kw)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    block_q=st.sampled_from([16, 32, 64]),
    block_k=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**30),
)
def test_block_shape_invariance(block_q, block_k, seed):
    """Property: the result must not depend on the tiling schedule."""
    key = jax.random.PRNGKey(seed)
    q, k, v = make_qkv(key, 1, 2, 2, 128, 32)
    base = flash_attention(q, k, v, variant="causal", block_q=64, block_k=64)
    out = flash_attention(
        q, k, v, variant="causal", block_q=block_q, block_k=block_k
    )
    np.testing.assert_allclose(out, base, atol=2e-5, rtol=2e-5)


def test_bf16_inputs_fp32_accumulation():
    """Paper §3.7: bf16 inputs accumulate in fp32, output stays bf16."""
    key = jax.random.PRNGKey(3)
    q, k, v = make_qkv(key, 1, 2, 2, 64, 32, jnp.bfloat16)
    out = flash_attention(q, k, v, variant="causal", block_q=32, block_k=32)
    assert out.dtype == jnp.bfloat16
    exp = ref.attention_ref(q, k, v, variant="causal")
    np.testing.assert_allclose(
        out.astype(np.float32), exp.astype(np.float32), atol=2e-2, rtol=2e-2
    )


def test_diff_attention():
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (1, 8, 64, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 64, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, 64, 32))
    out = diff_attention(q, k, v, 0.5, block_q=32, block_k=32)
    exp = ref.diff_attention_ref(q, k, v, 0.5)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_alibi_slopes_monotone():
    s = alibi_slope(jnp.arange(8), 8)
    assert np.all(np.diff(np.asarray(s)) < 0)
    assert float(s[7]) == pytest.approx(2.0 ** -8)


def test_fully_masked_rows_are_zero():
    """Sliding window of 0 width still keeps the diagonal; doc mask with a
    unique doc per position reduces to self-attention; no NaNs anywhere."""
    key = jax.random.PRNGKey(5)
    q, k, v = make_qkv(key, 1, 1, 1, 32, 16)
    out = flash_attention(
        q, k, v, variant="sliding_window", window=0, block_q=16, block_k=16
    )
    assert not np.any(np.isnan(np.asarray(out)))
    exp = ref.attention_ref(q, k, v, variant="sliding_window", window=0)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_rejects_bad_shapes():
    q = jnp.zeros((1, 3, 32, 16))
    k = jnp.zeros((1, 2, 32, 16))
    with pytest.raises(ValueError):
        flash_attention(q, k, k)
    with pytest.raises(ValueError):
        flash_attention(jnp.zeros((1, 2, 33, 16)), k, k)
    with pytest.raises(ValueError):
        flash_attention(jnp.zeros((1, 2, 32, 16)), k, k, variant="nope")
