"""L2 model tests: shapes, fused-vs-naive agreement, decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.LlamaConfig(vocab=128, d_model=64, n_layers=2, n_heads=4,
                    n_kv_heads=2, ffn_hidden=96, max_seq=64)


@pytest.fixture(scope="module")
def params():
    return M.init_llama(CFG, seed=0)


def test_prefill_shapes(params):
    tokens = jnp.arange(32, dtype=jnp.int32)[None, :] % CFG.vocab
    logits, kc, vc = M.llama_prefill(params, CFG, tokens)
    assert logits.shape == (1, 32, CFG.vocab)
    assert kc.shape == (CFG.n_layers, CFG.n_kv_heads, 32, CFG.head_dim)
    assert vc.shape == kc.shape


@pytest.mark.parametrize("variant", ["vanilla", "causal", "softcap"])
def test_prefill_fused_matches_naive(params, variant):
    """The flashlight and torch.compile-analog paths must agree numerically."""
    tokens = (jnp.arange(32, dtype=jnp.int32)[None, :] * 7) % CFG.vocab
    lf, kf, vf = M.llama_prefill(params, CFG, tokens, variant=variant, fused=True)
    ln, kn, vn = M.llama_prefill(params, CFG, tokens, variant=variant, fused=False)
    np.testing.assert_allclose(lf, ln, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(kf, kn, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(vf, vn, atol=1e-5, rtol=1e-5)


def test_decode_matches_prefill(params):
    """Prefilling S tokens then decoding must equal prefilling S+1 tokens.

    This is the KV-cache correctness invariant the serving path relies on.
    """
    b = 2
    seq = 16
    toks = (jnp.arange(seq + 1, dtype=jnp.int32) * 5 + 3) % CFG.vocab
    # Reference: causal prefill over seq+1 tokens.
    ref_logits, _, _ = M.llama_prefill(
        params, CFG, toks[None, :], variant="causal", fused=False
    )
    # Serving path: prefill seq tokens, scatter cache into slot, decode 1.
    _, kc, vc = M.llama_prefill(
        params, CFG, toks[None, :seq], variant="causal", fused=False
    )
    k_cache = jnp.zeros(
        (CFG.n_layers, b, CFG.n_kv_heads, CFG.max_seq, CFG.head_dim)
    )
    v_cache = jnp.zeros_like(k_cache)
    k_cache = k_cache.at[:, 0, :, :seq].set(kc)
    v_cache = v_cache.at[:, 0, :, :seq].set(vc)
    tokens = jnp.array([toks[seq], 0], dtype=jnp.int32)
    pos = jnp.array([seq, 0], dtype=jnp.int32)
    logits, nk, nv = M.llama_decode(params, CFG, tokens, pos, k_cache, v_cache)
    np.testing.assert_allclose(logits[0], ref_logits[0, -1], atol=1e-3, rtol=1e-3)
    # The decode step must have appended exactly one new cache entry.
    assert not np.allclose(nk[:, 0, :, seq], 0.0)
    np.testing.assert_allclose(nk[:, 0, :, :seq], kc, atol=1e-6)


def test_decode_slot_isolation(params):
    """Slot 1's cache/logits must be unaffected by slot 0's content."""
    b = 2
    k_cache = jnp.zeros((CFG.n_layers, b, CFG.n_kv_heads, CFG.max_seq,
                         CFG.head_dim))
    v_cache = jnp.zeros_like(k_cache)
    tokens = jnp.array([5, 9], dtype=jnp.int32)
    pos = jnp.array([0, 0], dtype=jnp.int32)
    l1, _, _ = M.llama_decode(params, CFG, tokens, pos, k_cache, v_cache)
    noisy_k = k_cache.at[:, 0].set(99.0)
    l2, _, _ = M.llama_decode(params, CFG, tokens, pos, noisy_k, v_cache)
    np.testing.assert_allclose(l1[1], l2[1], atol=1e-6)


def test_evoformer_block_fused_matches_naive():
    cfg = M.EvoformerConfig(n_rows=4, seq=32, d_model=32, n_heads=2, d_head=8,
                            d_transition=64)
    params = M.init_evoformer(cfg, seed=2)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, cfg.n_rows, cfg.seq, cfg.d_model))
    bias = 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1), (2, cfg.n_heads, cfg.seq, cfg.seq)
    )
    yf = M.evoformer_block(params, x, bias, fused=True)
    yn = M.evoformer_block(params, x, bias, fused=False)
    assert yf.shape == x.shape
    np.testing.assert_allclose(yf, yn, atol=1e-4, rtol=1e-4)


def test_rope_position_sensitivity():
    """RoPE must make attention position-dependent: shifting positions
    changes q/k projections."""
    x = jnp.ones((1, 4, 8))
    out0 = M._rope(x, jnp.arange(4)[None, :], 10000.0)
    out1 = M._rope(x, jnp.arange(4)[None, :] + 1, 10000.0)
    assert not np.allclose(out0, out1)
    # position 0 is the identity rotation
    np.testing.assert_allclose(out0[:, 0], x[:, 0], atol=1e-6)
