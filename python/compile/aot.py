"""AOT lowering: jax entry points -> HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (``make artifacts``):
  artifacts/<name>.hlo.txt      one per entry point
  artifacts/llama_weights.bin   flat little-endian f32 weight blob
  artifacts/evoformer_weights.bin
  artifacts/manifest.json       shapes/dtypes for every artifact + weights

Python runs once at build time and never on the request path.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref
from .kernels.flash_attention import diff_attention, flash_attention

# Canonical kernel-benchmark shape for the attention-variant artifacts.
ATTN_SHAPE = dict(B=1, H=4, HKV=4, S=128, D=64)
GQA_SHAPE = dict(B=1, H=8, HKV=2, S=128, D=64)
PREFILL_BUCKETS = (64, 256)
DECODE_BATCH = 8

LLAMA_CFG = M.LlamaConfig(vocab=512, d_model=256, n_layers=4, n_heads=8,
                          n_kv_heads=4, ffn_hidden=704, max_seq=512)
EVO_CFG = M.EvoformerConfig(n_rows=8, seq=64, d_model=64, n_heads=4, d_head=16)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[np.dtype(dt).name]


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest: dict = {"artifacts": {}, "weights": {}}

    def emit(self, name: str, fn, arg_specs, meta: dict | None = None):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *arg_specs)
        outs = jax.tree_util.tree_leaves(out_tree)
        self.manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
                for s in jax.tree_util.tree_leaves(arg_specs)
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": _dtype_name(o.dtype)}
                for o in outs
            ],
            "meta": meta or {},
        }
        print(f"  wrote {fname} ({len(text)} chars)")

    def emit_weights(self, name: str, leaves, names):
        blob = np.concatenate([np.asarray(l, np.float32).ravel() for l in leaves])
        fname = f"{name}_weights.bin"
        blob.tofile(os.path.join(self.out_dir, fname))
        self.manifest["weights"][name] = {
            "file": fname,
            "tensors": [
                {"name": n, "shape": list(np.asarray(l).shape)}
                for n, l in zip(names, leaves)
            ],
        }
        print(f"  wrote {fname} ({blob.nbytes} bytes)")

    def finish(self):
        self.manifest["llama_config"] = {
            "vocab": LLAMA_CFG.vocab, "d_model": LLAMA_CFG.d_model,
            "n_layers": LLAMA_CFG.n_layers, "n_heads": LLAMA_CFG.n_heads,
            "n_kv_heads": LLAMA_CFG.n_kv_heads, "head_dim": LLAMA_CFG.head_dim,
            "max_seq": LLAMA_CFG.max_seq, "prefill_buckets": list(PREFILL_BUCKETS),
            "decode_batch": DECODE_BATCH,
        }
        self.manifest["evoformer_config"] = {
            "n_rows": EVO_CFG.n_rows, "seq": EVO_CFG.seq,
            "d_model": EVO_CFG.d_model, "n_heads": EVO_CFG.n_heads,
        }
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print("  wrote manifest.json")
        # Line-based manifest for the (serde-less) rust runtime.
        lines = []
        for name, e in self.manifest["artifacts"].items():
            ins = " ".join(
                f"{t['dtype']}:{'x'.join(map(str, t['shape'])) or '0'}"
                for t in e["inputs"]
            )
            outs = " ".join(
                f"{t['dtype']}:{'x'.join(map(str, t['shape'])) or '0'}"
                for t in e["outputs"]
            )
            meta = " ".join(f"{k}={v}" for k, v in e["meta"].items())
            lines.append(f"artifact {name} {e['file']} in {ins} out {outs} meta {meta}")
        for family, w in self.manifest["weights"].items():
            tensors = " ".join(
                f"{t['name'].replace(' ', '')}:{'x'.join(map(str, t['shape']))}"
                for t in w["tensors"]
            )
            lines.append(f"weights {family} {w['file']} {tensors}")
        lc = self.manifest["llama_config"]
        lines.append(
            "config llama "
            + " ".join(
                f"{k}={v}"
                for k, v in lc.items()
                if k != "prefill_buckets"
            )
            + " prefill_buckets="
            + "/".join(map(str, lc["prefill_buckets"]))
        )
        ec = self.manifest["evoformer_config"]
        lines.append("config evoformer " + " ".join(f"{k}={v}" for k, v in ec.items()))
        with open(os.path.join(self.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(lines) + "\n")
        print("  wrote manifest.txt")


def _attn_entry(variant: str, fused: bool, shape: dict):
    """Build an attention entry: (q, k, v[, doc|bias]) -> (out,)."""
    S = shape["S"]

    def fn(q, k, v, *extra):
        kw = {}
        if variant == "sliding_window":
            kw["window"] = 32
        if variant == "softcap":
            kw["softcap"] = 20.0
        if variant == "prefix_lm":
            kw["prefix_len"] = 48
        if variant == "rectified":
            kw["tau"] = 0.1
        if variant == "document":
            kw["doc_ids"] = extra[0]
        if variant == "bias":
            kw["bias"] = extra[0]
        if fused:
            return (flash_attention(q, k, v, variant=variant,
                                    block_q=min(64, S), block_k=min(64, S), **kw),)
        return (ref.attention_ref(q, k, v, variant=variant, **kw),)

    specs = [
        _spec((shape["B"], shape["H"], S, shape["D"])),
        _spec((shape["B"], shape["HKV"], S, shape["D"])),
        _spec((shape["B"], shape["HKV"], S, shape["D"])),
    ]
    if variant == "document":
        specs.append(_spec((shape["B"], S), jnp.int32))
    if variant == "bias":
        specs.append(_spec((shape["B"], shape["H"], S, S)))
    return fn, specs


def emit_attention_variants(em: Emitter):
    print("== attention variant artifacts ==")
    for variant in ("vanilla", "causal", "sliding_window", "alibi",
                    "softcap", "prefix_lm", "document", "bias", "rectified"):
        for fused in (True, False):
            tag = "fused" if fused else "naive"
            fn, specs = _attn_entry(variant, fused, ATTN_SHAPE)
            em.emit(f"attn_{variant}_{tag}", fn, specs,
                    {"variant": variant, "fused": fused, **ATTN_SHAPE})
    for fused in (True, False):
        tag = "fused" if fused else "naive"
        fn, specs = _attn_entry("causal", fused, GQA_SHAPE)
        em.emit(f"attn_gqa_causal_{tag}", fn, specs,
                {"variant": "causal", "fused": fused, **GQA_SHAPE})
    # Differential attention (Listing 4): beyond the FlexAttention template.
    s = ATTN_SHAPE
    for fused in (True, False):
        tag = "fused" if fused else "naive"
        if fused:
            fn = lambda q, k, v: (diff_attention(q, k, v, 0.5, block_q=64,
                                                 block_k=64),)
        else:
            fn = lambda q, k, v: (ref.diff_attention_ref(q, k, v, 0.5),)
        em.emit(
            f"diff_attn_{tag}", fn,
            [
                _spec((s["B"], 2 * s["H"], s["S"], s["D"])),
                _spec((s["B"], 2 * s["H"], s["S"], s["D"])),
                _spec((s["B"], s["H"], s["S"], s["D"])),
            ],
            {"variant": "diff", "fused": fused, **s},
        )


def emit_llama(em: Emitter):
    print("== llama serving artifacts ==")
    cfg = LLAMA_CFG
    params = M.init_llama(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    names = [str(p) for p in
             jax.tree_util.tree_flatten_with_path(params)[0].__iter__()]
    names = [jax.tree_util.keystr(kp)
             for kp, _ in jax.tree_util.tree_flatten_with_path(params)[0]]
    em.emit_weights("llama", leaves, names)

    weight_specs = [_spec(l.shape) for l in leaves]

    for s in PREFILL_BUCKETS:
        for variant in ("vanilla", "causal", "softcap"):
            for fused in (True, False):
                tag = "fused" if fused else "naive"

                def fn(*args, _s=s, _variant=variant, _fused=fused):
                    ws, tokens = args[:-1], args[-1]
                    p = jax.tree_util.tree_unflatten(treedef, ws)
                    return M.llama_prefill(p, cfg, tokens, variant=_variant,
                                           fused=_fused)

                em.emit(
                    f"llama_prefill_{variant}_{tag}_s{s}", fn,
                    weight_specs + [_spec((1, s), jnp.int32)],
                    {"kind": "prefill", "variant": variant, "fused": fused,
                     "seq": s},
                )

    def decode_fn(*args):
        ws = args[:-4]
        tokens, pos, kc, vc = args[-4:]
        p = jax.tree_util.tree_unflatten(treedef, ws)
        return M.llama_decode(p, cfg, tokens, pos, kc, vc)

    b = DECODE_BATCH
    cache = (cfg.n_layers, b, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim)
    em.emit(
        f"llama_decode_b{b}", decode_fn,
        weight_specs
        + [_spec((b,), jnp.int32), _spec((b,), jnp.int32),
           _spec(cache), _spec(cache)],
        {"kind": "decode", "batch": b},
    )


def emit_evoformer(em: Emitter):
    print("== evoformer artifacts ==")
    cfg = EVO_CFG
    params = M.init_evoformer(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    names = [jax.tree_util.keystr(kp)
             for kp, _ in jax.tree_util.tree_flatten_with_path(params)[0]]
    em.emit_weights("evoformer", leaves, names)
    weight_specs = [_spec(l.shape) for l in leaves]
    x_spec = _spec((1, cfg.n_rows, cfg.seq, cfg.d_model))
    bias_spec = _spec((1, cfg.n_heads, cfg.seq, cfg.seq))
    for fused in (True, False):
        tag = "fused" if fused else "naive"

        def fn(*args, _fused=fused):
            ws, x, bias = args[:-2], args[-2], args[-1]
            p = jax.tree_util.tree_unflatten(treedef, ws)
            return (M.evoformer_block(p, x, bias, fused=_fused),)

        em.emit(f"evoformer_block_{tag}", fn, weight_specs + [x_spec, bias_spec],
                {"kind": "evoformer", "fused": fused})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    em = Emitter(args.out)
    emit_attention_variants(em)
    emit_llama(em)
    emit_evoformer(em)
    em.finish()
    print(f"AOT complete: {len(em.manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
