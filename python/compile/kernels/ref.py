"""Pure-jnp correctness oracle for the fused flash kernel.

This is also the "torch.compile baseline" analog on the real runtime path:
it materializes the (S, S) score and weight matrices exactly the way eager
PyTorch / default-Inductor attention does (paper Listing 1), so rust-side
serving benchmarks comparing fused vs naive artifacts measure the same
materialization cost the paper's torch.compile baseline pays.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .flash_attention import alibi_slope

NEG_INF = -1e30


def build_mask(
    variant: str,
    s: int,
    *,
    window: int | None = None,
    prefix_len: int | None = None,
    doc_ids: jax.Array | None = None,  # (B, S)
) -> jax.Array | None:
    """Boolean keep-mask of shape (S, S) (or (B, 1, S, S) for document)."""
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    if variant in ("vanilla", "bias"):
        return None
    if variant in ("causal", "alibi", "softcap"):
        return ki <= qi
    if variant == "sliding_window":
        w = window if window is not None else 256
        return (ki <= qi) & (qi - ki <= w)
    if variant == "prefix_lm":
        p = prefix_len if prefix_len is not None else 256
        return (ki <= qi) | (ki < p)
    if variant == "document":
        assert doc_ids is not None
        return (doc_ids[:, :, None] == doc_ids[:, None, :])[:, None, :, :]
    if variant == "rectified":
        return None  # data-dependent: handled on the scores directly
    raise ValueError(f"unknown variant {variant!r}")


def attention_ref(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,
    *,
    variant: str = "vanilla",
    window: int | None = None,
    softcap: float | None = None,
    prefix_len: int | None = None,
    tau: float | None = None,
    doc_ids: jax.Array | None = None,
    bias: jax.Array | None = None,
    sm_scale: float | None = None,
) -> jax.Array:
    """Naive two-pass attention: materializes scores, stable softmax, PV."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if group != 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    scores = (
        jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )
    if variant == "alibi":
        slopes = alibi_slope(jnp.arange(hq), hq)  # (H,)
        dist = (jnp.arange(s)[:, None] - jnp.arange(s)[None, :]).astype(jnp.float32)
        scores = scores - slopes[None, :, None, None] * dist[None, None]
    if variant == "softcap":
        cap = softcap if softcap is not None else 20.0
        scores = cap * jnp.tanh(scores / cap)
    if variant == "bias":
        assert bias is not None
        scores = scores + bias.astype(jnp.float32)
    mask = build_mask(
        variant, s, window=window, prefix_len=prefix_len, doc_ids=doc_ids
    )
    if variant == "rectified":
        t = tau if tau is not None else 0.0
        mask = scores >= t  # data-dependent keep-mask
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    # Stable two-pass softmax (paper Alg. 1): max, then shifted exp-sum.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    w = p / l
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def diff_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, lambda_full: float, **kw
) -> jax.Array:
    q0, q1 = jnp.split(q, 2, axis=1)
    k0, k1 = jnp.split(k, 2, axis=1)
    return attention_ref(q0, k0, v, **kw) - lambda_full * attention_ref(
        q1, k1, v, **kw
    )


def evoformer_gated_attention_ref(
    x: jax.Array,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    wg: jax.Array,
    wo: jax.Array,
    pair_bias: jax.Array,
) -> jax.Array:
    b, r, s, dm = x.shape
    h, d = wq.shape[1], wq.shape[2]
    q = jnp.einsum("brsm,mhd->brhsd", x, wq) * (1.0 / math.sqrt(d))
    kk = jnp.einsum("brsm,mhd->brhsd", x, wk)
    vv = jnp.einsum("brsm,mhd->brhsd", x, wv)
    scores = jnp.einsum("brhqd,brhkd->brhqk", q, kk) + pair_bias[:, None]
    w = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("brhqk,brhkd->brhqd", w, vv)
    gate = jax.nn.sigmoid(jnp.einsum("brsm,mhd->brhsd", x, wg))
    return jnp.einsum("brhsd,hdm->brsm", gate * attn, wo)
