"""L1: Pallas flash-attention kernel with fused variant score-mods.

This kernel is the analog of the Triton kernel Flashlight *generates*: a
single fused pass that computes ``softmax(score_mod(QK^T / sqrt(d))) V``
tile-by-tile with the online-softmax rewrite (paper §3.3/3.4), never
materializing the (S, S) score matrix.

Hardware adaptation (paper targets CUDA/Triton; see DESIGN.md §3):
  * CUDA threadblock over (q-tile) -> Pallas ``grid=(B, H, S/block_q)``;
    the inner kv loop is a ``lax.fori_loop`` over kv tiles.
  * Shared-memory staging -> ``BlockSpec`` HBM->VMEM schedule.
  * Tensor-core WMMA -> MXU-shaped ``jnp.dot`` with fp32 accumulation
    (``preferred_element_type=jnp.float32``), matching paper §3.7's
    unconditional FP32 promotion for bf16/fp16 inputs.
  * ``interpret=True`` everywhere: the CPU PJRT client cannot run Mosaic
    custom-calls; real-TPU efficiency is estimated in DESIGN.md §Perf.

Supported variants (paper §4.1 benchmarks):
  vanilla, causal, sliding_window, alibi, softcap, prefix_lm, document,
  bias (Evoformer-style additive bias). GQA is expressed through the kv
  ``BlockSpec`` index map (query head h reads kv head ``h // group``).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30  # finite stand-in for -inf: keeps the online rescale NaN-free

VARIANTS = (
    "vanilla",
    "causal",
    "sliding_window",
    "alibi",
    "softcap",
    "prefix_lm",
    "document",
    "bias",
    "rectified",
)


def alibi_slope(h: jax.Array | int, num_heads: int) -> jax.Array:
    """ALiBi slope for head ``h``: 2^(-8 (h+1) / H) (Press et al., 2022)."""
    return jnp.exp2(-8.0 * (jnp.float32(h) + 1.0) / jnp.float32(num_heads))


def _score_mod(
    variant: str,
    s: jax.Array,  # (block_q, block_k) raw scaled scores
    q_idx: jax.Array,  # (block_q,) absolute query positions
    k_idx: jax.Array,  # (block_k,) absolute key positions
    head: jax.Array,  # scalar query-head index
    num_heads: int,
    params: dict[str, Any],
    doc_q: jax.Array | None = None,  # (block_q,) document ids
    doc_k: jax.Array | None = None,  # (block_k,) document ids
    bias: jax.Array | None = None,  # (block_q, block_k) additive bias
) -> tuple[jax.Array, jax.Array]:
    """Apply the fused score modification. Returns (scores, keep_mask)."""
    qi = q_idx[:, None]
    ki = k_idx[None, :]
    keep = jnp.ones(s.shape, dtype=jnp.bool_)
    if variant == "vanilla":
        pass
    elif variant == "causal":
        keep = ki <= qi
    elif variant == "sliding_window":
        w = params["window"]
        keep = (ki <= qi) & (qi - ki <= w)
    elif variant == "alibi":
        # ALiBi is conventionally causal with a linear distance penalty.
        keep = ki <= qi
        s = s - alibi_slope(head, num_heads) * (qi - ki).astype(s.dtype)
    elif variant == "softcap":
        cap = params["softcap"]
        s = cap * jnp.tanh(s / cap)
        keep = ki <= qi  # paper's Softcap variant (Gemma-2 style) is causal
    elif variant == "prefix_lm":
        p = params["prefix_len"]
        keep = (ki <= qi) | (ki < p)
    elif variant == "document":
        keep = doc_q[:, None] == doc_k[None, :]
    elif variant == "bias":
        s = s + bias
    elif variant == "rectified":
        # RSA-style rectification: drop positions whose score is below
        # tau — a data-dependent mask (beyond FlexAttention's mask_mod).
        keep = s >= params["tau"]
    else:  # pragma: no cover - guarded by VARIANTS
        raise ValueError(f"unknown variant {variant!r}")
    return s, keep


def _flash_kernel(
    variant: str,
    num_heads: int,
    seq_len: int,
    block_q: int,
    block_k: int,
    sm_scale: float,
    params: dict[str, Any],
    *refs,
):
    """Fused online-softmax attention over one (batch, head, q-tile)."""
    has_doc = variant == "document"
    has_bias = variant == "bias"
    if has_doc:
        q_ref, k_ref, v_ref, doc_ref, o_ref = refs
    elif has_bias:
        q_ref, k_ref, v_ref, bias_ref, o_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref = refs

    head = pl.program_id(1)
    q_tile = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)  # (block_q, d)
    q_idx = q_tile * block_q + jnp.arange(block_q)

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(
            k_ref, (0, 0, pl.dslice(i * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        v = pl.load(
            v_ref, (0, 0, pl.dslice(i * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        k_idx = i * block_k + jnp.arange(block_k)
        # MXU matmul, fp32 accumulation (paper §3.7 precision handling).
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        doc_q = doc_k = bias = None
        if has_doc:
            doc_q = pl.load(doc_ref, (0, pl.dslice(q_tile * block_q, block_q)))
            doc_k = pl.load(doc_ref, (0, pl.dslice(i * block_k, block_k)))
        if has_bias:
            bias = pl.load(
                bias_ref,
                (
                    0,
                    0,
                    pl.dslice(q_tile * block_q, block_q),
                    pl.dslice(i * block_k, block_k),
                ),
            ).astype(jnp.float32)
        s, keep = _score_mod(
            variant, s, q_idx, k_idx, head, num_heads, params, doc_q, doc_k, bias
        )
        s = jnp.where(keep, s, NEG_INF)
        # Online softmax (paper Alg. 2 / §3.4): rescale running state.
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(keep, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    d = q_ref.shape[-1]
    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    m, l, acc = lax.fori_loop(0, seq_len // block_k, body, (m0, l0, acc0))
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows emit zeros, not NaNs
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    variant: str = "vanilla",
    window: int | None = None,
    softcap: float | None = None,
    prefix_len: int | None = None,
    tau: float | None = None,
    doc_ids: jax.Array | None = None,  # (B, S) int32
    bias: jax.Array | None = None,  # (B, Hq | 1, S, S)
    block_q: int | None = None,
    block_k: int | None = None,
    sm_scale: float | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Fused FlashAttention-style kernel for all evaluated variants.

    This is the single kernel Flashlight's compiler passes produce for the
    ``softmax(score_mod(QK^T)) V`` family; GQA is handled by the kv index
    map so kv heads are read ``Hq / Hkv`` times without materialization.
    """
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}, got {variant!r}")
    b, hq, s, d = q.shape
    _, hkv, sk, dk = k.shape
    if (sk, dk) != (s, d) or v.shape != k.shape:
        raise ValueError(f"shape mismatch q={q.shape} k={k.shape} v={v.shape}")
    if hq % hkv != 0:
        raise ValueError(f"Hq={hq} must be a multiple of Hkv={hkv}")
    group = hq // hkv
    block_q = min(block_q or 64, s)
    block_k = min(block_k or 64, s)
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} must be divisible by blocks ({block_q},{block_k})")
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)

    params: dict[str, Any] = {}
    if variant == "sliding_window":
        params["window"] = int(window if window is not None else 256)
    if variant == "softcap":
        params["softcap"] = float(softcap if softcap is not None else 20.0)
    if variant == "prefix_lm":
        params["prefix_len"] = int(prefix_len if prefix_len is not None else 256)
    if variant == "rectified":
        params["tau"] = float(tau if tau is not None else 0.0)

    grid = (b, hq, s // block_q)
    q_spec = pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi // group, 0, 0))
    in_specs = [q_spec, kv_spec, kv_spec]
    inputs: list[jax.Array] = [q, k, v]
    if variant == "document":
        if doc_ids is None:
            raise ValueError("document variant requires doc_ids")
        in_specs.append(pl.BlockSpec((1, s), lambda bi, hi, qi: (bi, 0)))
        inputs.append(doc_ids.astype(jnp.int32))
    if variant == "bias":
        if bias is None:
            raise ValueError("bias variant requires bias")
        hb = bias.shape[1]
        in_specs.append(
            pl.BlockSpec(
                (1, 1, s, s), lambda bi, hi, qi: (bi, 0 if hb == 1 else hi, 0, 0)
            )
        )
        inputs.append(bias)

    kernel = functools.partial(
        _flash_kernel, variant, hq, s, block_q, block_k, sm_scale, params
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(*inputs)


def diff_attention(
    q: jax.Array,  # (B, 2H, S, D) - chunked into two halves along heads
    k: jax.Array,  # (B, 2H, S, D)
    v: jax.Array,  # (B, H, S, D)
    lambda_full: float,
    **kw,
) -> jax.Array:
    """Differential attention (Ye et al., 2024), paper Listing 4.

    Not expressible in the FlexAttention template; Flashlight compiles it
    to two fused attention kernels plus a fused pointwise epilogue.
    """
    q0, q1 = jnp.split(q, 2, axis=1)
    k0, k1 = jnp.split(k, 2, axis=1)
    a0 = flash_attention(q0, k0, v, **kw)
    a1 = flash_attention(q1, k1, v, **kw)
    return a0 - lambda_full * a1


def evoformer_gated_attention(
    x: jax.Array,  # (B, R, S, Dm) MSA-style activations
    wq: jax.Array,  # (Dm, H, D)
    wk: jax.Array,
    wv: jax.Array,
    wg: jax.Array,  # (Dm, H, D) gate projection
    wo: jax.Array,  # (H, D, Dm)
    pair_bias: jax.Array,  # (B, H, S, S), broadcast over rows R
) -> jax.Array:
    """Row-wise gated self-attention from AlphaFold's Evoformer (paper §4.3).

    Uses an additional row dimension and a pair bias broadcast along it —
    beyond the FlexAttention template. The attention core runs through the
    fused kernel; projections and the sigmoid gate are pointwise epilogues
    XLA fuses around it.
    """
    b, r, s, dm = x.shape
    h, d = wq.shape[1], wq.shape[2]
    q = jnp.einsum("brsm,mhd->brhsd", x, wq) * (1.0 / math.sqrt(d))
    kk = jnp.einsum("brsm,mhd->brhsd", x, wk)
    vv = jnp.einsum("brsm,mhd->brhsd", x, wv)
    # Flatten (B, R) into the kernel batch; bias index maps back to b = br // R.
    qf = q.reshape(b * r, h, s, d)
    kf = kk.reshape(b * r, h, s, d)
    vf = vv.reshape(b * r, h, s, d)
    bias_rep = jnp.repeat(pair_bias, r, axis=0)  # (B*R, H, S, S)
    attn = flash_attention(qf, kf, vf, variant="bias", bias=bias_rep, sm_scale=1.0)
    attn = attn.reshape(b, r, h, s, d)
    gate = jax.nn.sigmoid(jnp.einsum("brsm,mhd->brhsd", x, wg))
    out = gate * attn
    return jnp.einsum("brhsd,hdm->brsm", out, wo)
