"""L2: JAX model definitions lowered to AOT artifacts.

Two model families, both build-time only (Python never serves requests):

* ``TinyLlama`` — a small LLaMa-style decoder (RMSNorm, RoPE, GQA, SwiGLU)
  with prefill and decode-step entry points. The prefill attention runs
  either through the fused L1 Pallas kernel ("flashlight" artifacts) or
  the materializing jnp reference ("naive" artifacts = the torch.compile
  baseline on the real runtime path). Weights are baked into the HLO as
  constants so the rust runtime only feeds tokens and KV caches.

* ``EvoformerBlock`` — AlphaFold-style row-wise gated self-attention plus
  transition, for the end-to-end AlphaFold experiment (paper §4.4).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels.flash_attention import flash_attention, evoformer_gated_attention
from .kernels.ref import attention_ref, evoformer_gated_attention_ref


# ---------------------------------------------------------------------------
# Tiny LLaMa-style decoder
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LlamaConfig:
    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    ffn_hidden: int = 704
    max_seq: int = 512
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def init_llama(cfg: LlamaConfig, seed: int = 0) -> dict[str, Any]:
    """Deterministic random init (the serving paper needs no training)."""
    key = jax.random.PRNGKey(seed)
    ks = iter(jax.random.split(key, 4 + 9 * cfg.n_layers))

    def lin(k, fan_in, shape):
        return jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan_in)

    d, hd = cfg.d_model, cfg.head_dim
    params: dict[str, Any] = {
        "embed": lin(next(ks), 1.0, (cfg.vocab, d)),
        "unembed": lin(next(ks), d, (d, cfg.vocab)),
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "attn_norm": jnp.ones((d,), jnp.float32),
                "wq": lin(next(ks), d, (d, cfg.n_heads * hd)),
                "wk": lin(next(ks), d, (d, cfg.n_kv_heads * hd)),
                "wv": lin(next(ks), d, (d, cfg.n_kv_heads * hd)),
                "wo": lin(next(ks), cfg.n_heads * hd, (cfg.n_heads * hd, d)),
                "ffn_norm": jnp.ones((d,), jnp.float32),
                "w_gate": lin(next(ks), d, (d, cfg.ffn_hidden)),
                "w_up": lin(next(ks), d, (d, cfg.ffn_hidden)),
                "w_down": lin(next(ks), cfg.ffn_hidden, (cfg.ffn_hidden, d)),
            }
        )
    return params


def _rms_norm(x: jax.Array, g: jax.Array) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def _rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, D), pos: (..., S) absolute positions."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)  # (D/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape)


def _attn_proj(layer, x, cfg: LlamaConfig, pos):
    """Project to (q, k, v) heads with RoPE applied. x: (B, S, D)."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = (x @ layer["wq"]).reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = (x @ layer["wk"]).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (x @ layer["wv"]).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    q = _rope(q, pos[:, None, :], cfg.rope_theta)
    k = _rope(k, pos[:, None, :], cfg.rope_theta)
    return q, k, v


def _ffn(layer, x):
    return (jax.nn.silu(x @ layer["w_gate"]) * (x @ layer["w_up"])) @ layer["w_down"]


def llama_prefill(
    params: dict[str, Any],
    cfg: LlamaConfig,
    tokens: jax.Array,  # (1, S) int32
    *,
    variant: str = "causal",
    fused: bool = True,
    softcap: float = 20.0,
):
    """Full prefill pass. Returns (per-position logits, k-cache, v-cache).

    Logits are returned for every position (B, S, V) so the rust
    coordinator can read the logits of the *real* last token when the
    prompt is right-padded to a bucket length. Caches have shape
    (L, Hkv, S, Dh); the coordinator copies them into the batched decode
    cache at the request's slot (padded positions are later overwritten
    by the decode scatter and masked by `ki <= pos`).
    """
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"][tokens]  # (B, S, D)
    k_caches, v_caches = [], []
    for layer in params["layers"]:
        h = _rms_norm(x, layer["attn_norm"])
        q, k, v = _attn_proj(layer, h, cfg, pos)
        k_caches.append(k[0])
        v_caches.append(v[0])
        if fused:
            attn = flash_attention(
                q, k, v, variant=variant, softcap=softcap,
                block_q=min(64, s), block_k=min(64, s),
            )
        else:
            attn = attention_ref(q, k, v, variant=variant, softcap=softcap)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, -1)
        x = x + attn @ layer["wo"]
        x = x + _ffn(layer, _rms_norm(x, layer["ffn_norm"]))
    x = _rms_norm(x, params["final_norm"])
    logits = x @ params["unembed"]  # (B, S, V)
    return logits, jnp.stack(k_caches), jnp.stack(v_caches)


def llama_decode(
    params: dict[str, Any],
    cfg: LlamaConfig,
    tokens: jax.Array,  # (B,) int32 — last generated token per slot
    pos: jax.Array,  # (B,) int32 — number of tokens already cached per slot
    k_cache: jax.Array,  # (L, B, Hkv, Smax, Dh)
    v_cache: jax.Array,
):
    """One batched decode step over the padded slot batch.

    Inactive slots run with pos=0 and are ignored by the coordinator
    (classic padded continuous batching). Attends to cache[:pos]+self.
    """
    b = tokens.shape[0]
    smax = k_cache.shape[3]
    x = params["embed"][tokens][:, None, :]  # (B, 1, D)
    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        h = _rms_norm(x, layer["attn_norm"])
        q, k, v = _attn_proj(layer, h, cfg, pos[:, None])  # q: (B,H,1,Dh)
        # Scatter this step's k/v into the cache at position `pos`.
        kc = jax.vmap(
            lambda cache, kv, p: jax.lax.dynamic_update_slice(
                cache, kv, (0, p, 0)
            )
        )(k_cache[li], k, pos)
        vc = jax.vmap(
            lambda cache, kv, p: jax.lax.dynamic_update_slice(
                cache, kv, (0, p, 0)
            )
        )(v_cache[li], v, pos)
        new_k.append(kc)
        new_v.append(vc)
        # Single-query attention over valid prefix (ki <= pos).
        group = cfg.n_heads // cfg.n_kv_heads
        kf = jnp.repeat(kc, group, axis=1)  # (B, H, Smax, Dh)
        vf = jnp.repeat(vc, group, axis=1)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, kf) / math.sqrt(cfg.head_dim)
        ki = jnp.arange(smax)[None, None, None, :]
        scores = jnp.where(ki <= pos[:, None, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bhqd", w, vf)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        x = x + attn @ layer["wo"]
        x = x + _ffn(layer, _rms_norm(x, layer["ffn_norm"]))
    x = _rms_norm(x, params["final_norm"])
    logits = x[:, 0, :] @ params["unembed"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# ---------------------------------------------------------------------------
# Evoformer block (AlphaFold row-wise gated self-attention + transition)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EvoformerConfig:
    n_rows: int = 8
    seq: int = 64
    d_model: int = 64
    n_heads: int = 4
    d_head: int = 16
    d_transition: int = 256


def init_evoformer(cfg: EvoformerConfig, seed: int = 1) -> dict[str, Any]:
    key = jax.random.PRNGKey(seed)
    ks = iter(jax.random.split(key, 8))

    def lin(k, fan_in, shape):
        return jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan_in)

    dm, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    return {
        "wq": lin(next(ks), dm, (dm, h, dh)),
        "wk": lin(next(ks), dm, (dm, h, dh)),
        "wv": lin(next(ks), dm, (dm, h, dh)),
        "wg": lin(next(ks), dm, (dm, h, dh)),
        "wo": lin(next(ks), h * dh, (h, dh, dm)),
        "w_t1": lin(next(ks), dm, (dm, cfg.d_transition)),
        "w_t2": lin(next(ks), cfg.d_transition, (cfg.d_transition, dm)),
    }


def evoformer_block(
    params: dict[str, Any],
    x: jax.Array,  # (B, R, S, Dm)
    pair_bias: jax.Array,  # (B, H, S, S)
    *,
    fused: bool = True,
) -> jax.Array:
    fn = evoformer_gated_attention if fused else evoformer_gated_attention_ref
    x = x + fn(
        x, params["wq"], params["wk"], params["wv"], params["wg"], params["wo"],
        pair_bias,
    )
    x = x + jax.nn.relu(x @ params["w_t1"]) @ params["w_t2"]
    return x
