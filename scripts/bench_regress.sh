#!/usr/bin/env bash
# Perf-regression driver: build release, gate the test suite under
# FOUR configurations (default SIMD dispatch, FLASHLIGHT_SIMD=0 scalar
# tier, FLASHLIGHT_TOPO=flat single-domain scheduling, and
# FLASHLIGHT_BLOCKMASK=0 dense execution), run `flashlight lint`
# (static plan verification), `flashlight chaos --live` (live serving
# invariants), and `flashlight chaos --shards` (sharded serving:
# 1/2/4-way sharding x threads must be bit-identical; kill@R:shard=S
# plans must fail over with exact terminal accounting and no leaks on
# surviving shards), then run the benches and record two perf
# trajectories at the repo root so future PRs have a baseline:
#   BENCH_parallel_engine.json  sequential vs parallel executor wall
#                               clock per variant, plus the GEMM/softmax
#                               microkernel table
#   BENCH_serve_engine.json     engine-backend serve matrix (tok/s,
#                               TTFT p50/p99, cache + gather gates),
#                               lifecycle-chaos and goodput-load rows,
#                               and the sharded cells (shard_scaling,
#                               shard_kill)
#
# NOTE: seeding the BENCH_*.json trajectories requires the rust
# toolchain. On hosts without cargo this script fails fast with a
# clear message instead of silently writing nothing.
#
# Usage: scripts/bench_regress.sh [--quick] [--chaos] [--gate NAME] [THREADS]
#   --quick      engine + serve benches only: skip the criterion-style
#                figure benches (compiler_micro, fig2/fig3) — the CI loop
#   --chaos      also replay the serving lifecycle under three seeded
#                fault plans (pool exhaustion, worker panics, cancels,
#                deadline storms)
#   --gate NAME  run exactly one named gate and its summary row; names:
#                build test_default test_scalar test_flat_topo
#                test_dense lint chaos_live chaos_shards bench_engine
#                bench_serve bench_figures chaos_replay
#   THREADS      worker threads for the parallel runs (default: all cores)
#
# Every run ends with a PASS/FAIL summary table; exit status is
# non-zero if any executed gate failed.

set -uo pipefail
cd "$(dirname "$0")/.."

QUICK=0
CHAOS=0
THREADS=0 # 0 = all available cores
ONLY_GATE=""
while [ $# -gt 0 ]; do
  case "$1" in
    --quick) QUICK=1 ;;
    --chaos) CHAOS=1 ;;
    --gate)
      shift
      ONLY_GATE="${1:?--gate needs a name}"
      ;;
    *) THREADS="$1" ;;
  esac
  shift
done

if ! command -v cargo >/dev/null 2>&1; then
  echo "FATAL: no rust toolchain (cargo) on PATH — cannot run any gate" >&2
  echo "       or seed the BENCH_*.json perf trajectories." >&2
  exit 1
fi

GATE_NAMES=()
GATE_RESULTS=()
FAILED=0

# run_gate NAME DESCRIPTION... — runs gate_NAME, records PASS/FAIL.
# With --gate set, every other gate is skipped silently.
run_gate() {
  local name="$1"
  shift
  if [ -n "$ONLY_GATE" ] && [ "$name" != "$ONLY_GATE" ]; then
    return 0
  fi
  echo
  echo "== gate $name: $* =="
  if "gate_$name"; then
    GATE_NAMES+=("$name")
    GATE_RESULTS+=("PASS")
  else
    GATE_NAMES+=("$name")
    GATE_RESULTS+=("FAIL")
    FAILED=1
  fi
}

print_summary() {
  echo
  echo "== gate summary =="
  printf '%-16s %s\n' "gate" "result"
  if [ "${#GATE_NAMES[@]}" -gt 0 ]; then
    local i
    for i in "${!GATE_NAMES[@]}"; do
      printf '%-16s %s\n' "${GATE_NAMES[$i]}" "${GATE_RESULTS[$i]}"
    done
  else
    echo "(no gates ran — unknown --gate name?)"
    FAILED=1
  fi
  if [ "$FAILED" -eq 1 ]; then
    echo "RESULT: FAIL"
  else
    echo "RESULT: PASS"
  fi
}

gate_build() { cargo build --release; }

gate_test_default() { cargo test -q; }

gate_test_scalar() { FLASHLIGHT_SIMD=0 cargo test -q; }

# The whole suite — including every bit-identity gate — must hold with
# topology-aware sharding collapsed to one flat domain. A failure here
# means scheduling topology leaked into numerics, which the runtime's
# determinism contract forbids.
gate_test_flat_topo() { FLASHLIGHT_TOPO=flat cargo test -q; }

# The suite must hold with the block-sparse tile layer killed (every
# k-tile visited, masks evaluated everywhere). A failure means sparse
# vs dense execution is not equivalent — or dense execution regressed
# while hiding behind the sparse fast path.
gate_test_dense() { FLASHLIGHT_BLOCKMASK=0 cargo test -q; }

# The static verifier must prove every built-in variant x bucket-ladder
# shape clean — shape re-inference, grid write-set disjointness, the
# online-softmax determinism contract, and block-mask skip soundness.
gate_lint() { cargo run --release -- lint; }

# Live serving: open-loop arrivals into a bounded queue, seeded
# exponential-backoff resubmission, per-request token streams, and
# watchdog-supervised stalled launches must hold every lifecycle
# invariant at 1/2/4 threads on the round clock (plus a threaded
# wall-clock ingress/drain smoke).
gate_chaos_live() {
  cargo run --release -- chaos --live --requests 20 \
    --plans 'seed=4,stall@3,pressure@2:6x8;panic@4;cancel@6:1'
}

# Sharded serving (seventh gate): the determinism half requires the
# same trace sharded 1/2/4 ways, at 1/2/4 threads per shard, to emit
# bit-identical per-request token streams; the failover half kills a
# shard mid-trace (explicitly and via seeded generated plans) and
# requires exactly one terminal per admitted request, survivors
# bit-identical to the fault-free reference, and
# allocated == free + parked on every surviving shard.
gate_chaos_shards() {
  cargo run --release -- chaos --shards 2 --requests 12 --threads 2 \
    --plans 'kill@3:shard=0,seed=5,pressure@2:6x6;kill@4:shard=1'
}

gate_bench_engine() {
  cargo run --release -- bench engine --threads "$THREADS"
}

gate_bench_serve() {
  cargo run --release -- bench serve_engine
}

gate_bench_figures() {
  cargo bench --bench compiler_micro && cargo bench --bench fig2_fig3_variants
}

# Three deterministic plans: two seeded schedules plus an explicit
# worst case (pressure window + worker panic + cancel + deadline
# storm). `chaos` exits non-zero if any request misses its single
# terminal state, any KV page leaks, or any survivor's token stream
# diverges from the fault-free run.
gate_chaos_replay() {
  cargo run --release -- chaos --requests 24 --threads 2 \
    --plans 'seed=1,seed=2,pressure@2:6x8;panic@3;cancel@5:1;storm@9:2'
}

run_gate build "cargo build --release"
if [ "$FAILED" -eq 1 ]; then
  print_summary
  exit 1
fi
run_gate test_default "cargo test -q (default SIMD dispatch)"
run_gate test_scalar "cargo test -q (FLASHLIGHT_SIMD=0: scalar tier)"
run_gate test_flat_topo "cargo test -q (FLASHLIGHT_TOPO=flat: single-domain scheduling)"
run_gate test_dense "cargo test -q (FLASHLIGHT_BLOCKMASK=0: dense, no tile skipping)"
run_gate lint "static plan verification"
run_gate chaos_live "live serving invariants"
run_gate chaos_shards "sharded serving: determinism + shard failover"
if [ "$QUICK" -eq 0 ] || [ "$ONLY_GATE" = "bench_figures" ]; then
  run_gate bench_figures "criterion figure benches (compiler_micro, fig2/fig3)"
fi
run_gate bench_engine "seq vs par per variant + microkernels -> BENCH_parallel_engine.json"
run_gate bench_serve "engine serve matrix + sharded cells -> BENCH_serve_engine.json"
if [ "$CHAOS" -eq 1 ] || [ "$ONLY_GATE" = "chaos_replay" ]; then
  run_gate chaos_replay "lifecycle invariants under seeded fault plans"
fi

if [ -z "$ONLY_GATE" ] && [ "$FAILED" -eq 0 ]; then
  for f in BENCH_parallel_engine.json BENCH_serve_engine.json; do
    if [ -f "$f" ]; then
      echo
      echo "wrote $(pwd)/$f:"
      cat "$f"
    fi
  done
fi

print_summary
[ "$FAILED" -eq 0 ]
