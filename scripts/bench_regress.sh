#!/usr/bin/env bash
# Perf-regression driver: build release, gate the test suite under
# FOUR configurations (default SIMD dispatch, FLASHLIGHT_SIMD=0 scalar
# tier, FLASHLIGHT_TOPO=flat single-domain scheduling, and
# FLASHLIGHT_BLOCKMASK=0 dense execution — the last two fail loudly if
# any bit-identity gate diverges between modes), run `flashlight lint`
# as a fifth gate (static plan verification over every built-in
# variant x bucket shape), run `flashlight chaos --live` as a sixth
# gate (live serving: open-loop arrivals, backoff resubmission, token
# streams, watchdog-killed stalls — FATAL on any leak, missing
# terminal, or survivor-stream divergence), run the benches, and
# record two perf trajectories at the repo root so future PRs have a
# baseline to compare against:
#   BENCH_parallel_engine.json  sequential vs parallel executor wall
#                               clock per variant, plus the GEMM/softmax
#                               microkernel table (GFLOP/s, scalar tier
#                               vs dispatched tier)
#   BENCH_serve_engine.json     engine-backend serve matrix: tok/s and
#                               TTFT p50/p99 for chunked prefill on/off
#                               x L in {1,4} layers, each at 1/2/all
#                               threads with the bit-identity gate,
#                               plan-cache warmup stats, the
#                               zero-gather-alloc / zero-post-warmup-
#                               plan-build gates, and goodput-vs-
#                               offered-load rows (open-loop Poisson
#                               arrivals reduced per rate)
#
# Usage: scripts/bench_regress.sh [--quick] [--chaos] [THREADS]
#   --quick  engine + serve benches only: skip the criterion-style
#            figure benches (compiler_micro, fig2/fig3) — the CI loop
#   --chaos  also replay the serving lifecycle under three seeded
#            fault plans (pool exhaustion, worker panics, cancels,
#            deadline storms); fails loudly on a leaked page, a missing
#            terminal state, or a survivor token stream that diverges
#            from the fault-free run
#   THREADS  worker threads for the parallel runs (default: all cores)

set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
CHAOS=0
THREADS=0 # 0 = all available cores
for arg in "$@"; do
  case "$arg" in
    --quick) QUICK=1 ;;
    --chaos) CHAOS=1 ;;
    *) THREADS="$arg" ;;
  esac
done

echo "== cargo build --release =="
cargo build --release

echo
echo "== cargo test -q (default SIMD dispatch) =="
cargo test -q

echo
echo "== cargo test -q (FLASHLIGHT_SIMD=0: scalar tier) =="
FLASHLIGHT_SIMD=0 cargo test -q

echo
echo "== cargo test -q (FLASHLIGHT_TOPO=flat: single-domain scheduling) =="
# Third gate configuration: the whole suite — including every
# bit-identity gate — must hold with topology-aware sharding collapsed
# to one flat domain. A failure here means scheduling topology leaked
# into numerics, which the runtime's determinism contract forbids.
if ! FLASHLIGHT_TOPO=flat cargo test -q; then
  echo >&2
  echo "FATAL: test suite diverges under FLASHLIGHT_TOPO=flat —" >&2
  echo "       a bit-identity gate depends on the scheduling topology." >&2
  exit 1
fi

echo
echo "== cargo test -q (FLASHLIGHT_BLOCKMASK=0: dense, no tile skipping) =="
# Fourth gate configuration: the whole suite must hold with the
# block-sparse tile layer killed (every k-tile visited, masks evaluated
# everywhere). A failure here means sparse execution leaked into
# results somewhere the bit-identity contract forbids — or that dense
# execution regressed while hiding behind the sparse fast path.
if ! FLASHLIGHT_BLOCKMASK=0 cargo test -q; then
  echo >&2
  echo "FATAL: test suite diverges under FLASHLIGHT_BLOCKMASK=0 —" >&2
  echo "       sparse vs dense execution is not equivalent." >&2
  exit 1
fi

echo
echo "== flashlight lint (fifth gate: static plan verification) =="
# Fifth gate: the static verifier must prove every built-in variant x
# bucket-ladder shape clean — shape re-inference, grid write-set
# disjointness, the online-softmax determinism contract, and
# block-mask skip soundness. Any diagnostic is a planner bug.
if ! cargo run --release -- lint; then
  echo >&2
  echo "FATAL: static plan verification failed — a generated plan" >&2
  echo "       violates a fusion legality / determinism / race-freedom" >&2
  echo "       invariant; see the diagnostics above." >&2
  exit 1
fi

echo
echo "== flashlight chaos --live (sixth gate: live serving invariants) =="
# Sixth gate: the live serving path — open-loop arrivals into a bounded
# queue, seeded exponential-backoff resubmission, per-request token
# streams, and watchdog-supervised stalled launches — must hold every
# lifecycle invariant at 1/2/4 threads on the round clock (plus a
# threaded wall-clock ingress/drain smoke). `chaos --live` exits
# non-zero on a leaked page, a missing terminal state, a token stream
# that disagrees with its outcome, or a survivor stream that diverges
# across thread counts or from the fault-free reference.
if ! cargo run --release -- chaos --live --requests 20 \
    --plans 'seed=4,stall@3,pressure@2:6x8;panic@4;cancel@6:1'; then
  echo >&2
  echo "FATAL: live serving invariant violated — a page leaked, a" >&2
  echo "       request missed its terminal state, or a survivor's" >&2
  echo "       token stream diverged; reproduce with" >&2
  echo "       cargo run --release -- chaos --live --plans '<spec>'" >&2
  exit 1
fi

if [ "$QUICK" -eq 0 ]; then
  echo
  echo "== compiler-micro bench =="
  cargo bench --bench compiler_micro

  echo
  echo "== fig2/fig3 variants bench (cost-model series + measured executor) =="
  cargo bench --bench fig2_fig3_variants
fi

echo
echo "== parallel engine: seq vs par per variant + microkernels -> BENCH_parallel_engine.json =="
cargo run --release -- bench engine --threads "$THREADS"

echo
echo "== serve throughput: engine backend, chunking x layers matrix -> BENCH_serve_engine.json =="
cargo run --release -- bench serve_engine

if [ "$CHAOS" -eq 1 ]; then
  echo
  echo "== chaos: lifecycle invariants under seeded fault plans =="
  # Three deterministic plans: two seeded schedules plus an explicit
  # worst-case (pressure window + worker panic + cancel + deadline
  # storm). `chaos` exits non-zero if any request misses its single
  # terminal state, any KV page leaks, or any survivor's token stream
  # diverges from the fault-free run.
  if ! cargo run --release -- chaos --requests 24 --threads 2 \
      --plans 'seed=1,seed=2,pressure@2:6x8;panic@3;cancel@5:1;storm@9:2'; then
    echo >&2
    echo "FATAL: lifecycle invariant violated under fault injection —" >&2
    echo "       see the failing plan above; reproduce with" >&2
    echo "       cargo run --release -- chaos --plans '<spec>'" >&2
    exit 1
  fi
fi

echo
echo "wrote $(pwd)/BENCH_parallel_engine.json:"
cat BENCH_parallel_engine.json
echo
echo "wrote $(pwd)/BENCH_serve_engine.json:"
cat BENCH_serve_engine.json
