//! Build probe for the AVX-512 kernel tier.
//!
//! The `_mm512_*` / masked-`_mm256_*` intrinsics the tier uses were
//! stabilized in rustc 1.89. The offline build image pins whatever
//! toolchain it ships, so instead of a hard MSRV bump the tier is
//! compiled only when the active rustc can build it:
//! `cfg(flashlight_avx512)` gates `exec/simd/x86_512.rs`, its
//! `SimdLevel::Avx512` dispatch arms, and the `detect()` probe. On
//! older toolchains (or non-x86_64 targets) the engine silently tops
//! out at the AVX2+FMA tier — behavior, tests, and bit-identity gates
//! are unaffected, only peak kernel throughput.

use std::process::Command;

fn rustc_at_least(major: u32, minor: u32) -> bool {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".into());
    let out = match Command::new(&rustc).arg("--version").output() {
        Ok(o) => o,
        Err(_) => return false,
    };
    let text = String::from_utf8_lossy(&out.stdout);
    // "rustc 1.89.0 (…)" — take the second token, split on non-digits.
    let ver = text.split_whitespace().nth(1).unwrap_or("");
    let mut parts = ver.split(|c: char| !c.is_ascii_digit());
    let maj: u32 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(0);
    let min: u32 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(0);
    (maj, min) >= (major, minor)
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    let x86_64 = std::env::var("CARGO_CFG_TARGET_ARCH").as_deref() == Ok("x86_64");
    if rustc_at_least(1, 80) {
        // Declare the custom cfg so `unexpected_cfgs` stays quiet on
        // toolchains that know check-cfg (stable since 1.80).
        println!("cargo:rustc-check-cfg=cfg(flashlight_avx512)");
    }
    if x86_64 && rustc_at_least(1, 89) {
        println!("cargo:rustc-cfg=flashlight_avx512");
    }
}
