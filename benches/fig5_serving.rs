//! `cargo bench --bench fig5_serving`
//!
//! Regenerates Figure 5 (Mooncake-like trace, LLaMa-3.2-1B shapes,
//! Flashlight vs FlexAttention) on the simulated H100, and — when AOT
//! artifacts are present — a short real PJRT serving run of the tiny
//! model with fused vs naive attention.

use flashlight::cost::h100;
use flashlight::serve;

fn main() -> anyhow::Result<()> {
    serve::bench_fig5(&h100())?;

    if std::path::Path::new("artifacts/manifest.txt").exists() && cfg!(feature = "pjrt") {
        println!("\n== real PJRT serving (tiny model, fused vs naive) ==");
        serve::cli_serve(
            16,
            "pjrt",
            flashlight::exec::Parallelism::available(),
            serve::EngineServeOpts::default(),
        )?;
    } else {
        println!("artifacts or pjrt feature missing; skipping real PJRT serving bench");
    }
    Ok(())
}
