//! `cargo bench --bench fig4_complex`
//!
//! Regenerates Figure 4 (DiffAttn + Evoformer vs torch.compile) and
//! the §4.4 AlphaFold table, plus a measured run of both complex
//! variants through the fused tiled executor.

use flashlight::bench::{bench_fn, figures};
use flashlight::cost::{a100, h100};
use flashlight::exec::{eval, execute_plan, Tensor};
use flashlight::fusion::{plan, FusionMode, TileConfig};
use flashlight::ir::Op;
use flashlight::variants::{build, AttnShape, Variant};

fn main() -> anyhow::Result<()> {
    figures::fig4(&[h100(), a100()])?;
    figures::alphafold(&h100())?;

    println!("\n== measured executor wall-clock: complex variants ==");
    for (v, shape) in [
        (
            Variant::DiffAttn { lambda: 0.5 },
            AttnShape {
                batch: 1,
                rows: 1,
                heads_q: 4,
                heads_kv: 4,
                seq: 64,
                head_dim: 16,
            },
        ),
        (Variant::Evoformer, AttnShape::evoformer(1, 8, 64, 16)),
    ] {
        let g = build(v, &shape);
        let mut inputs = std::collections::HashMap::new();
        for (i, &id) in g.inputs.iter().enumerate() {
            let Op::Input { name } = &g.node(id).op else { unreachable!() };
            inputs.insert(name.clone(), Tensor::synthetic(&g.node(id).shape, i as u64));
        }
        let p = plan(&g, FusionMode::Flashlight);
        let tc = plan(&g, FusionMode::TorchCompile);
        let tile = TileConfig {
            block_q: 32,
            block_k: 32,
            ..Default::default()
        };
        let st_f = bench_fn(2, 5, || {
            let _ = execute_plan(&g, &p, &inputs, tile);
        });
        let st_e = bench_fn(2, 5, || {
            let _ = eval(&g, &inputs);
        });
        let (_, cf) = execute_plan(&g, &p, &inputs, tile);
        let (_, ct) = execute_plan(&g, &tc, &inputs, tile);
        println!(
            "{:<12} kernels fl={} tc={} | wall eager {:.2} ms fused {:.2} ms | traffic tc/fl {:.1}x",
            v.name(),
            p.groups.len(),
            tc.groups.len(),
            st_e.mean_s * 1e3,
            st_f.mean_s * 1e3,
            ct.total_traffic() as f64 / cf.total_traffic() as f64
        );
    }
    Ok(())
}
