//! `cargo bench --bench compiler_micro`
//!
//! L3 hot-path microbenchmarks (the §Perf targets in DESIGN.md):
//! planner latency per variant graph, fused-executor throughput, the
//! online-softmax row update, and logical-grid delinearization.

use flashlight::bench::bench_fn;
use flashlight::exec::{execute_plan, Tensor};
use flashlight::fusion::{plan, FusionMode, OnlineRowState, TileConfig};
use flashlight::grid::{LogicalGrid, TiledDim};
use flashlight::ir::Op;
use flashlight::variants::{build, paper_variants, AttnShape};

fn main() {
    let shape = AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 8,
        heads_kv: 2,
        seq: 1024,
        head_dim: 64,
    };

    println!("== planner latency (target: < 1 ms per variant graph) ==");
    for v in paper_variants() {
        let g = build(v, &shape);
        let st = bench_fn(3, 20, || {
            let p = plan(&g, FusionMode::Flashlight);
            assert!(p.num_pipelines() >= 1);
        });
        println!("  {:<16} {:>9.1} us", v.name(), st.mean_us());
    }

    println!("== fused executor throughput (S=256, B=1, H=4, d=32) ==");
    let shape = AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 4,
        heads_kv: 4,
        seq: 256,
        head_dim: 32,
    };
    let g = build(flashlight::variants::Variant::Causal, &shape);
    let mut inputs = std::collections::HashMap::new();
    for (i, &id) in g.inputs.iter().enumerate() {
        let Op::Input { name } = &g.node(id).op else { unreachable!() };
        inputs.insert(name.clone(), Tensor::synthetic(&g.node(id).shape, i as u64));
    }
    let p = plan(&g, FusionMode::Flashlight);
    let tile = TileConfig {
        block_q: 64,
        block_k: 64,
        ..Default::default()
    };
    let st = bench_fn(2, 10, || {
        let _ = execute_plan(&g, &p, &inputs, tile);
    });
    let (_, c) = execute_plan(&g, &p, &inputs, tile);
    println!(
        "  {:>9.2} ms/iter  ({:.1} Mflop/s scalar)",
        st.mean_s * 1e3,
        c.flops as f64 / st.mean_s / 1e6
    );

    println!("== online softmax row update (d=64, 16 kv tiles) ==");
    let scores: Vec<f32> = (0..1024).map(|i| (i % 97) as f32 * 0.03 - 1.0).collect();
    let v: Vec<f32> = (0..1024 * 64).map(|i| (i % 31) as f32 * 0.01).collect();
    let st = bench_fn(3, 30, || {
        let mut s = OnlineRowState::new(64);
        for t in 0..16 {
            s.update(
                &scores[t * 64..(t + 1) * 64],
                &v[t * 64 * 64..(t + 1) * 64 * 64],
            );
        }
        std::hint::black_box(s.finish());
    });
    println!(
        "  {:>9.2} us per 1024-kv row  ({:.2} Gelem/s)",
        st.mean_us(),
        1024.0 * 64.0 / st.mean_s / 1e9
    );

    println!("== logical grid delinearize ==");
    let grid = LogicalGrid::new(vec![
        TiledDim {
            size: 1 << 22,
            tile: 16,
        },
        TiledDim {
            size: 1 << 10,
            tile: 16,
        },
    ]);
    let n = grid.n_blocks().min(1 << 20);
    let st = bench_fn(2, 10, || {
        let mut acc = 0usize;
        for id in 0..n {
            acc += grid.delinearize(id)[0];
        }
        std::hint::black_box(acc);
    });
    println!("  {:>9.2} ns per block id", st.mean_s / n as f64 * 1e9);
}
