//! `cargo bench --bench fig2_fig3_variants`
//!
//! Regenerates Figures 2 and 3 (cost-model series over the compiler's
//! plans) and wall-clock-benches the fused tiled executor against the
//! eager reference on scaled-down shapes — the real, measured execution
//! behind the modeled numbers.

use flashlight::bench::{bench_fn, figures};
use flashlight::cost::{a100, h100};
use flashlight::exec::{eval, execute_plan, Tensor};
use flashlight::fusion::{plan, FusionMode, TileConfig};
use flashlight::ir::Op;
use flashlight::variants::{build, paper_variants, AttnShape, Variant};

fn inputs_for(g: &flashlight::ir::Graph) -> std::collections::HashMap<String, Tensor> {
    let mut m = std::collections::HashMap::new();
    for (i, &id) in g.inputs.iter().enumerate() {
        let node = g.node(id);
        let Op::Input { name } = &node.op else { unreachable!() };
        let t = if name.starts_with("doc") {
            let n: usize = node.shape.iter().product();
            Tensor::from_vec(&node.shape, (0..n).map(|j| (j * 4 / n) as f32).collect())
        } else {
            Tensor::synthetic(&node.shape, 7 + i as u64)
        };
        m.insert(name.clone(), t);
    }
    m
}

fn main() -> anyhow::Result<()> {
    // The paper's series (modeled on H100 + A100).
    figures::fig2_fig3(&h100(), false)?;
    figures::fig2_fig3(&a100(), false)?;

    // Measured: fused tiled executor vs eager reference, per variant.
    println!("\n== measured executor wall-clock (S=128, B=1, H=4, d=32) ==");
    println!(
        "{:<16} {:>12} {:>12} {:>8}",
        "variant", "eager(ms)", "fused(ms)", "traffic x"
    );
    let shape = AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 4,
        heads_kv: 2,
        seq: 128,
        head_dim: 32,
    };
    for v in paper_variants() {
        let v = match v {
            Variant::SlidingWindow { .. } => Variant::SlidingWindow { window: 32 },
            Variant::PrefixLm { .. } => Variant::PrefixLm { prefix: 48 },
            other => other,
        };
        let g = build(v, &shape);
        let inputs = inputs_for(&g);
        let p = plan(&g, FusionMode::Flashlight);
        let tile = TileConfig {
            block_q: 32,
            block_k: 32,
            ..Default::default()
        };
        let st_eager = bench_fn(2, 5, || {
            let _ = eval(&g, &inputs);
        });
        let st_fused = bench_fn(2, 5, || {
            let _ = execute_plan(&g, &p, &inputs, tile);
        });
        let (_, ce) = eval(&g, &inputs);
        let (_, cf) = execute_plan(&g, &p, &inputs, tile);
        println!(
            "{:<16} {:>12.3} {:>12.3} {:>8.1}",
            v.name(),
            st_eager.mean_s * 1e3,
            st_fused.mean_s * 1e3,
            ce.total_traffic() as f64 / cf.total_traffic() as f64
        );
    }
    Ok(())
}
